//! The [`Scheduler`] trait and its [`ContinuousBatcher`]
//! implementation — request-lifecycle serving over the lane API of
//! [`AttentionSession`].
//!
//! A step is the scheduling quantum. Each [`Scheduler::step`]:
//!
//! 1. **Admits** queued requests into free lanes under the page-budget
//!    policy: a request reserves its worst-case page footprint
//!    (`heads · ⌈(prompt + max_new) / page_size⌉`) at admission, so a
//!    live wave can never run out of pages mid-decode. Admission is
//!    FIFO with head-of-line blocking — a request that doesn't fit
//!    *yet* waits (pages drain as sequences finish); a request that
//!    could *never* fit fails at submission. Requests carrying an
//!    interactive [`SloClass`](crate::serve::request::SloClass) are
//!    admitted before batch-class requests and may preempt batch
//!    lanes under pressure (restart semantics — streams are
//!    bit-for-bit preserved).
//! 2. **Prefills** each admitted request at its own boundary (batch-1,
//!    its own prompt length — no padding to a wave-wide length) and
//!    samples its first token: time-to-first-token does not wait for
//!    any other sequence. With `ServeConfig::prefill_chunk > 0` the
//!    prompt is instead ingested **incrementally**: each step every
//!    mid-prefill lane advances by at most one chunk before the
//!    decode pass runs, so a long prompt interleaves with live decode
//!    lanes instead of stalling them.
//! 3. **Decodes** one token for every live sequence of every engine
//!    group in one mixed batch per group, then **releases finished
//!    lanes' pages on the same step** — the mid-wave eviction that
//!    makes room for the next admission.
//!
//! Heterogeneous engine families coexist in one scheduler: requests
//! are grouped by canonical engine spec, one `AttentionSession` (and
//! page budget) per group. The queue/group/lifecycle state every
//! scheduler needs lives in [`SchedulerCore`], shared with the
//! [`WaveScheduler`](crate::serve::wave::WaveScheduler) baseline so
//! the two differ only in policy.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::attention::decode::PagedKvPolicy;
use crate::attention::registry::{parse_spec, validate_draft_spec};
use crate::attention::session::{AttentionSession, LaneId, PrefillState, SessionConfig};
use crate::attention::HeadTensor;
use crate::coordinator::metrics::ServeMetrics;
use crate::kv_cache::paged::{KvTierCfg, TierPolicy};
use crate::kv_cache::radix::{EntryId, PrefixCacheStats, PrefixHit, RadixPrefixCache};
use crate::serve::model::{sample, ToyLm};
use crate::serve::request::{
    FinishReason, FinishedRequest, RequestId, RequestState, ServeError, ServeEvent,
    ServeRequest, ServeSampling,
};
use crate::serve::speculate::{verify_emit, SpeculateConfig};
use crate::util::rng::Rng;

/// Radix prompt-prefix cache knobs (`ServeConfig::prefix_cache`).
/// Composes with the batcher's admission accounting: cached entries are
/// charged a nominal `heads × ⌈len / page_size⌉` pages against the same
/// `max_pages` budget the lane reservations draw from, and admissions
/// under pressure evict least-recently-used entries first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheConfig {
    /// Nominal page budget the cache may hold per engine group.
    pub max_pages: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> PrefixCacheConfig {
        PrefixCacheConfig { max_pages: 1024 }
    }
}

/// Geometry and policy knobs shared by every serve scheduler.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub heads: usize,
    /// Q/K/V dim per head.
    pub d: usize,
    pub vocab: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// KV page budget *per engine group* (each distinct canonical spec
    /// owns its own paged cache).
    pub max_pages: usize,
    /// Maximum concurrently-live sequences across all groups.
    pub max_lanes: usize,
    /// Admission queue bound — `submit` returns
    /// [`ServeError::QueueFull`] beyond it.
    pub queue_capacity: usize,
    /// Context cap: prompt plus generated tokens per sequence.
    pub max_seq: usize,
    /// Seed for the deterministic [`ToyLm`] and per-request samplers.
    pub model_seed: u64,
    /// KV eviction policy for every admitted lane. `None` (default)
    /// keeps worst-case `prompt + max_new` page reservations; `Some`
    /// switches the [`ContinuousBatcher`] to **policy-budget
    /// admission**: each lane reserves only its pruned steady-state
    /// footprint (see [`pages_reserved`]), so more lanes fit the same
    /// page budget. The wave baseline ignores this (it *is* the
    /// worst-case comparison point).
    pub kv_policy: Option<PagedKvPolicy>,
    /// Radix prompt-prefix cache. `Some` makes the
    /// [`ContinuousBatcher`] record each finished request's prompt
    /// path (pinned forked pages, never copies) and seed later
    /// admissions from the longest cached prefix, prefilling only the
    /// un-shared suffix — repeated-system-prompt workloads stop paying
    /// per-request prefill. Mutually exclusive with `kv_policy`
    /// (pruned lanes hold policy-dependent KV, which a shared prefix
    /// must not). The wave baseline ignores this (it is the cold
    /// comparison point).
    pub prefix_cache: Option<PrefixCacheConfig>,
    /// Chunked-prefill quantum in prompt tokens. `0` (default) keeps
    /// the legacy monolithic path: a request's whole prompt is
    /// ingested in its admission step, stalling every live decode
    /// lane for the duration. `N > 0` makes the [`ContinuousBatcher`]
    /// interleave: each step, every mid-prefill lane advances by at
    /// most `N` prompt tokens and then all fully-prefilled lanes
    /// decode one token — a long prompt no longer blocks short
    /// requests' tokens. Greedy streams are bit-for-bit identical
    /// across chunk sizes (including 0): chunking changes *when*
    /// cache bytes land, never which bytes, and the first token is
    /// always sampled from the cache-scored last prompt position.
    /// The wave baseline ignores this (monolithic is its semantics).
    pub prefill_chunk: usize,
    /// Speculative decoding. `Some` makes the [`ContinuousBatcher`]
    /// run draft-and-verify decode steps: a cheap draft engine
    /// proposes up to γ tokens per step, the target engine verifies
    /// all γ+1 positions in one multi-position forward on a
    /// `fork_prefix`-forked lane, and the exact-match acceptance rule
    /// ([`crate::serve::speculate`]) keeps the agreed prefix — so
    /// token streams are **bit-for-bit identical** with speculation on
    /// or off, for greedy and temperature sampling alike. Mutually
    /// exclusive with `kv_policy`: a policy observes exactly one
    /// position per decode step, which a multi-position verify would
    /// not reproduce. Composes with `prefix_cache` and
    /// `prefill_chunk` (draft lanes are seeded lazily at the first
    /// speculative step, after the target prefill completes). The
    /// wave baseline ignores this.
    pub speculate: Option<SpeculateConfig>,
    /// Tiered KV storage. `Some` makes the [`ContinuousBatcher`] demote
    /// each lane's cold pages (everything but the newest `cold_after`
    /// tokens under the `lru` policy, or the tokens the lane's eviction
    /// policy marks cold under `h2o`) to per-row int8 after every
    /// decode pass. A demoted page costs **half** a page against the
    /// budget, so admission — which charges tiered requests at their
    /// compressed steady state ([`pages_reserved_tiered`]) — fits more
    /// concurrent lanes into the same `max_pages`. Reads are
    /// tier-transparent (cold pages dequantize into scratch), which
    /// perturbs attention by at most the int8 round-trip error
    /// (≤ scale/2 per element); streams are bit-for-bit identical
    /// whenever no page is ever demoted (e.g. every sequence shorter
    /// than `cold_after`). `h2o` tiering requires `kv_policy`; mutually
    /// exclusive with `speculate` (a verify fork must reproduce the
    /// target's cache bytes exactly, which mid-stream requantization
    /// breaks). The wave baseline ignores this.
    pub kv_tier: Option<KvTierCfg>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            heads: 4,
            d: 32,
            vocab: 64,
            page_size: 16,
            max_pages: 4096,
            max_lanes: 8,
            queue_capacity: 1024,
            max_seq: 4096,
            model_seed: 0x5FA,
            kv_policy: None,
            prefix_cache: None,
            prefill_chunk: 0,
            speculate: None,
            kv_tier: None,
        }
    }
}

/// Why a [`ServeConfig`] failed construction-time validation — the
/// typed error [`ServeConfig::validate`] and [`ServeConfigBuilder::build`]
/// return, so CLI layers report the violated constraint instead of
/// panicking deep inside a scheduler constructor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeConfigError(pub String);

impl std::fmt::Display for ServeConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ServeConfigError {}

impl ServeConfig {
    /// Construction-time sanity, as a typed result: a zero in any of
    /// these knobs makes a scheduler that can never admit work (e.g.
    /// `max_lanes == 0` turns `step()` into a busy-wait that never
    /// drains the queue), and some feature pairs are semantically
    /// incompatible. This is the single source of truth — the builder,
    /// the panicking constructors, and CLI validation all delegate here.
    pub fn validate(&self) -> Result<(), ServeConfigError> {
        fn fail(msg: &str) -> Result<(), ServeConfigError> {
            Err(ServeConfigError(msg.to_string()))
        }
        if self.heads < 1 || self.d < 1 || self.vocab < 2 {
            return fail("degenerate model geometry");
        }
        if self.page_size < 1 || self.max_pages < 1 {
            return fail("degenerate page budget");
        }
        if self.max_lanes < 1 {
            return fail("max_lanes must be >= 1 (a 0-lane scheduler never admits)");
        }
        if self.queue_capacity < 1 {
            return fail("queue_capacity must be >= 1");
        }
        if self.max_seq < 2 {
            return fail("max_seq must fit a prompt token plus a generated token");
        }
        if self.kv_policy.is_some() && self.prefix_cache.is_some() {
            return fail(
                "prefix_cache and kv_policy are mutually exclusive: a policy-pruned lane holds \
                 policy-dependent KV that a shared prefix must not serve",
            );
        }
        if let Some(px) = &self.prefix_cache {
            if px.max_pages < 1 {
                return fail("prefix_cache.max_pages must be >= 1");
            }
        }
        if let Some(sp) = &self.speculate {
            if sp.gamma < 1 {
                return fail("speculate.gamma must be >= 1");
            }
            if self.kv_policy.is_some() {
                return fail(
                    "speculate and kv_policy are mutually exclusive: a policy observes one \
                     position per decode step, which a multi-position verify cannot reproduce",
                );
            }
        }
        if let Some(tier) = &self.kv_tier {
            if tier.cold_after < 1 {
                return fail("kv_tier.cold_after must be >= 1 (the newest token stays hot)");
            }
            if self.speculate.is_some() {
                return fail(
                    "kv_tier and speculate are mutually exclusive: a verify fork must read the \
                     target's exact cache bytes, which mid-stream int8 demotion perturbs",
                );
            }
            if tier.policy == TierPolicy::H2o && self.kv_policy.is_none() {
                return fail(
                    "kv_tier policy `h2o` requires kv_policy: the demote verdicts come from the \
                     lanes' eviction-policy scores",
                );
            }
        }
        Ok(())
    }

    /// Panicking shim over [`Self::validate`] for the internal
    /// constructors (tests construct configs by struct literal and want
    /// a loud failure, not error plumbing).
    pub(crate) fn assert_valid(&self) {
        if let Err(e) = self.validate() {
            panic!("{e}");
        }
    }

    /// A checked builder over [`ServeConfig::default`]:
    /// [`ServeConfigBuilder::build`] runs [`Self::validate`] and
    /// returns the typed error, so misconfiguration surfaces at
    /// construction — before a scheduler exists — instead of as a panic
    /// inside `SchedulerCore::new`.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    /// Drop every continuous-batcher-only feature in one place — the
    /// config a baseline scheduler (the deprecated wave path) actually
    /// implements. Baselines must go through this helper rather than
    /// hand-stripping fields, so a newly added knob cannot silently
    /// leak into the baseline and diverge the comparison.
    pub fn strip_incompatible(mut self) -> ServeConfig {
        self.kv_policy = None;
        self.prefix_cache = None;
        self.prefill_chunk = 0;
        self.speculate = None;
        self.kv_tier = None;
        self
    }
}

/// Checked construction for [`ServeConfig`] (see
/// [`ServeConfig::builder`]). Setters mirror the config fields
/// one-to-one; [`Self::build`] validates and returns the typed
/// [`ServeConfigError`] instead of panicking.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn heads(mut self, heads: usize) -> Self {
        self.cfg.heads = heads;
        self
    }
    pub fn d(mut self, d: usize) -> Self {
        self.cfg.d = d;
        self
    }
    pub fn vocab(mut self, vocab: usize) -> Self {
        self.cfg.vocab = vocab;
        self
    }
    pub fn page_size(mut self, page_size: usize) -> Self {
        self.cfg.page_size = page_size;
        self
    }
    pub fn max_pages(mut self, max_pages: usize) -> Self {
        self.cfg.max_pages = max_pages;
        self
    }
    pub fn max_lanes(mut self, max_lanes: usize) -> Self {
        self.cfg.max_lanes = max_lanes;
        self
    }
    pub fn queue_capacity(mut self, queue_capacity: usize) -> Self {
        self.cfg.queue_capacity = queue_capacity;
        self
    }
    pub fn max_seq(mut self, max_seq: usize) -> Self {
        self.cfg.max_seq = max_seq;
        self
    }
    pub fn model_seed(mut self, model_seed: u64) -> Self {
        self.cfg.model_seed = model_seed;
        self
    }
    pub fn kv_policy(mut self, kv_policy: Option<PagedKvPolicy>) -> Self {
        self.cfg.kv_policy = kv_policy;
        self
    }
    pub fn prefix_cache(mut self, prefix_cache: Option<PrefixCacheConfig>) -> Self {
        self.cfg.prefix_cache = prefix_cache;
        self
    }
    pub fn prefill_chunk(mut self, prefill_chunk: usize) -> Self {
        self.cfg.prefill_chunk = prefill_chunk;
        self
    }
    pub fn speculate(mut self, speculate: Option<SpeculateConfig>) -> Self {
        self.cfg.speculate = speculate;
        self
    }
    pub fn kv_tier(mut self, kv_tier: Option<KvTierCfg>) -> Self {
        self.cfg.kv_tier = kv_tier;
        self
    }

    /// Validate and hand back the config, or the first violated
    /// constraint as a [`ServeConfigError`].
    pub fn build(self) -> Result<ServeConfig, ServeConfigError> {
        self.cfg.validate()?;
        Ok(self.cfg)
    }
}

/// Worst-case page footprint of one sequence: `steps` generated tokens
/// on top of a `prompt_len` prompt, across `heads` per-head sequences.
/// Public so CLI layers pre-check workloads with the *same* formula
/// the admission policy reserves by.
pub fn pages_needed(prompt_len: usize, steps: usize, heads: usize, page_size: usize) -> usize {
    heads * (prompt_len + steps).div_ceil(page_size)
}

/// Pages one request reserves at admission under the configured
/// policy. Worst-case mode (`kv_policy: None`) reserves the full
/// `prompt + steps` footprint. Policy-budget mode reserves the pruned
/// steady state `min(prompt + steps, policy_limit + 1)` tokens (`+1`
/// covers the append that precedes each prune) — the long-prompt
/// prefill spike above that is a *transient*: `prefill_lane` prunes the
/// lane back under budget before the admission pass moves on, so the
/// batcher checks it against the momentarily free pool instead of
/// reserving it for the lane's lifetime.
pub fn pages_reserved(prompt_len: usize, steps: usize, cfg: &ServeConfig) -> usize {
    match &cfg.kv_policy {
        None => pages_needed(prompt_len, steps, cfg.heads, cfg.page_size),
        Some(p) => {
            let peak = (prompt_len + steps).min(p.max_cached_tokens(cfg.page_size) + 1);
            cfg.heads * peak.div_ceil(cfg.page_size)
        }
    }
}

/// Pages a request reserves when the first `shared` prompt tokens come
/// from a cached prefix: the whole pages covering the shared prefix
/// (`⌊shared / page_size⌋` per head) belong to the prefix-cache entry
/// (charged against its own nominal budget), so the lane is charged
/// only its un-shared suffix — a partially-shared last page counts to
/// the lane, because the first suffix append copy-on-writes it into a
/// lane-owned page. With `shared == 0` this is exactly
/// [`pages_reserved`] in worst-case mode.
pub fn pages_reserved_shared(
    prompt_len: usize,
    steps: usize,
    shared: usize,
    cfg: &ServeConfig,
) -> usize {
    debug_assert!(shared <= prompt_len);
    let total = pages_needed(prompt_len, steps, cfg.heads, cfg.page_size);
    total - cfg.heads * (shared / cfg.page_size)
}

/// Pages one request reserves at admission under **tiered** KV storage
/// (`ServeConfig::kv_tier`): start from the untied reservation
/// ([`pages_reserved`], or [`pages_reserved_shared`] on a prefix hit)
/// and discount the pages that will sit cold at steady state — every
/// full page below the newest `cold_after` tokens demotes to int8 at
/// half cost, refunding `⌊cold_pages / 2⌋` whole pages per head.
/// Shared-prefix pages belong to the prefix cache's own nominal budget
/// and are excluded from the discount. With `kv_tier: None` this is
/// bit-for-bit the untied reservation — the seed-accounting identity
/// the no-demotion stream pin rests on.
pub fn pages_reserved_tiered(
    prompt_len: usize,
    steps: usize,
    shared: usize,
    cfg: &ServeConfig,
) -> usize {
    let base = if shared > 0 {
        pages_reserved_shared(prompt_len, steps, shared, cfg)
    } else {
        pages_reserved(prompt_len, steps, cfg)
    };
    let Some(tier) = cfg.kv_tier else {
        return base;
    };
    // Steady-state cached tokens: the policy-pruned footprint when a
    // kv_policy caps it, the whole stream otherwise.
    let tokens = match &cfg.kv_policy {
        None => prompt_len + steps,
        Some(p) => (prompt_len + steps).min(p.max_cached_tokens(cfg.page_size) + 1),
    };
    let cold_full_pages = (tokens.saturating_sub(tier.cold_after) / cfg.page_size)
        .saturating_sub(shared / cfg.page_size);
    base.saturating_sub(cfg.heads * (cold_full_pages / 2))
}

/// What one [`Scheduler::step`] did (the serving loop's observability
/// surface; `bench serve` integrates these into page-occupancy curves).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepReport {
    /// Requests admitted (prefilled) this step.
    pub admitted: usize,
    /// Prompt tokens ingested by the chunked-prefill pass this step
    /// (0 under the monolithic path, which ingests inside admission).
    pub prefill_tokens: usize,
    /// Tokens sampled this step (prefill first-tokens + decode).
    pub decoded_tokens: usize,
    pub finished: usize,
    pub failed: usize,
    /// KV pages returned to the budget this step by finished lanes.
    pub pages_freed: usize,
    /// KV pages returned to the budget this step by policy eviction
    /// (live lanes pruning themselves under their policy budget).
    pub pages_pruned: usize,
    /// Admissions this step that forked a cached prompt prefix
    /// (prefix-cache hits; zero unless `ServeConfig::prefix_cache`).
    pub prefix_hits: usize,
    /// Draft tokens accepted by speculative verify steps this step
    /// (zero unless `ServeConfig::speculate`). Each accepted token is
    /// a decode token the target engine got "for free" — also counted
    /// in `decoded_tokens`.
    pub spec_accepted: usize,
    /// Batch-class lanes preempted this step to admit interactive
    /// requests under lane/page pressure (restart semantics: the
    /// preempted request re-queues at its original position and
    /// regenerates the identical stream — zero unless SLO classes mix).
    pub preempted: usize,
    /// Pages demoted to the int8 cold tier this step (lane tiering
    /// under `ServeConfig::kv_tier` plus radix-cache
    /// demote-before-drop; zero when neither fires).
    pub pages_demoted: usize,
    /// Cold pages promoted back to f32 this step (appends landing on
    /// a demoted tail, prefix-cache borrows of a demoted entry).
    pub pages_promoted: usize,
    /// KV pages in use across all groups after the step.
    pub pages_in_use: usize,
    /// Budget consumed in half-page units (fp32 page = 2, int8 = 1)
    /// across all groups after the step — `2 * pages_in_use` exactly
    /// while nothing is demoted. `bench serve --kv-tier` derives the
    /// effective-capacity ratio `2 * pages_in_use / kv_units_in_use`
    /// from this (1.0 all-hot, → 2.0 as everything demotes).
    pub kv_units_in_use: usize,
    /// Live sequences after the step.
    pub live: usize,
}

/// A request-lifecycle scheduler: submit → step until idle → collect.
pub trait Scheduler {
    /// Enqueue a request; typed errors for backpressure and
    /// never-fits requests. `Ok` hands back the request's id.
    fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError>;

    /// Run one scheduling quantum (admissions + one decode step).
    fn step(&mut self) -> StepReport;

    /// Anything queued or mid-flight?
    fn has_work(&self) -> bool;

    /// Current lifecycle state of a request (pruned once its terminal
    /// summary is drained by [`Scheduler::take_finished`]).
    fn state(&self, id: RequestId) -> Option<&RequestState>;

    /// Drain terminal request summaries accumulated so far.
    fn take_finished(&mut self) -> Vec<FinishedRequest>;

    fn metrics(&self) -> &ServeMetrics;
    fn metrics_mut(&mut self) -> &mut ServeMetrics;

    /// KV pages in use across all engine groups.
    fn pages_in_use(&self) -> usize;

    /// Prompt-prefix cache counters summed across engine groups
    /// (all-zero for schedulers without a prefix cache).
    fn prefix_stats(&self) -> PrefixCacheStats {
        PrefixCacheStats::default()
    }

    /// Worst per-element dequantization error seen by any cold-tier
    /// demotion so far, as a fraction of the quantizer's `scale/2`
    /// bound (`<= 1.0` means within contract; 0.0 for schedulers
    /// without a cold tier).
    fn tier_error_ratio(&self) -> f32 {
        0.0
    }

    /// Step until idle, then drain the terminal summaries.
    fn run_to_completion(&mut self) -> Vec<FinishedRequest> {
        while self.has_work() {
            self.step();
        }
        self.take_finished()
    }
}

/// Validation shared by every scheduler's `submit`.
pub(crate) fn validate(req: &ServeRequest, cfg: &ServeConfig) -> Result<(), ServeError> {
    if req.prompt.is_empty() {
        return Err(ServeError::EmptyPrompt);
    }
    if req.max_new == 0 {
        return Err(ServeError::NothingToGenerate);
    }
    let target = parse_spec(&req.engine)?;
    if let Some(sp) = &cfg.speculate {
        // Draft/target compatibility is per-request (targets are a
        // request property): reject drafts that are nonsense for this
        // target before the request ever reaches a lane.
        validate_draft_spec(&sp.draft, &target)?;
    }
    if req.prompt.len() + 1 > cfg.max_seq {
        return Err(ServeError::PromptTooLong { len: req.prompt.len(), max_seq: cfg.max_seq });
    }
    let budget_tokens = req.max_new.min(cfg.max_seq - req.prompt.len());
    // A request never fits if its steady-state reservation *or* its
    // prefill-time transient (the whole prompt is paged in before the
    // post-prefill prune) exceeds an empty cache.
    let needed = pages_reserved_tiered(req.prompt.len(), budget_tokens, 0, cfg)
        .max(pages_needed(req.prompt.len(), 0, cfg.heads, cfg.page_size));
    if needed > cfg.max_pages {
        return Err(ServeError::PageBudgetExceeded {
            needed_pages: needed,
            budget_pages: cfg.max_pages,
        });
    }
    Ok(())
}

pub(crate) fn emit(req: &ServeRequest, ev: ServeEvent) {
    if let Some(tx) = &req.events {
        let _ = tx.send(ev); // streaming consumer may have gone away
    }
}

pub(crate) fn set_state(
    states: &mut BTreeMap<RequestId, RequestState>,
    req: &ServeRequest,
    id: RequestId,
    state: RequestState,
) {
    emit(req, ServeEvent::State { id, state: state.clone() });
    states.insert(id, state);
}

/// One request waiting for admission.
pub(crate) struct QueuedReq {
    pub id: RequestId,
    pub req: ServeRequest,
    pub submitted: Instant,
}

/// One live sequence occupying a lane.
pub(crate) struct ActiveSeq {
    pub id: RequestId,
    pub req: ServeRequest,
    pub lane: LaneId,
    pub last_token: i32,
    pub generated: Vec<i32>,
    /// Generation cap: `min(max_new, max_seq - prompt_len)`.
    pub budget: usize,
    /// Pages reserved for this sequence at admission (the un-shared
    /// suffix only, when `prefix` is a hit).
    pub reserved_pages: usize,
    /// Prefix-cache hit backing this lane: the borrowed entry and the
    /// shared prompt-token count. The borrow is released exactly once,
    /// at retirement or failure.
    pub prefix: Option<(EntryId, usize)>,
    /// Per-request sampler stream (independent of batch composition).
    pub rng: Rng,
    pub submitted: Instant,
    pub last_token_at: Instant,
    pub ttft_s: f64,
    /// Wave scheduling only: finished but still holding its lane.
    pub done: Option<FinishReason>,
    /// Chunked prefill in flight (`ServeConfig::prefill_chunk > 0`):
    /// prompt-ingestion progress. `None` once the prompt is fully
    /// cached — only then does the lane join decode batches. Until the
    /// first token is sampled, `last_token`/`generated`/`ttft_s` hold
    /// placeholder values.
    pub prefill: Option<PrefillState>,
    /// Speculative decoding: this sequence's lane in the group's
    /// *draft* session, mirroring the stream prefix the target lane
    /// has cached. Seeded lazily at the first speculative step and
    /// reconciled (re-forked or extended) after each verify; `None`
    /// when speculation is off or the draft pool is momentarily out of
    /// pages (the lane decodes plainly until it can be re-seeded).
    pub draft_lane: Option<LaneId>,
}

/// All sequences sharing one engine spec (and one session / cache).
pub(crate) struct EngineGroup {
    /// Canonical spec string.
    pub spec: String,
    pub session: AttentionSession,
    pub active: Vec<ActiveSeq>,
    /// Worst-case pages promised to live sequences.
    pub reserved_pages: usize,
    /// Radix prompt-prefix cache over this group's paged cache
    /// (`ServeConfig::prefix_cache`; continuous batcher only).
    pub prefix: Option<RadixPrefixCache>,
    /// Speculative decoding: the group's draft-engine session
    /// (`ServeConfig::speculate`), with its own page pool — draft KV
    /// is the memory cost of speculation and never touches the target
    /// budget or its reservation accounting.
    pub draft: Option<AttentionSession>,
}

impl EngineGroup {
    /// Return one sequence's reservation to the pool — exactly once.
    /// Checked subtraction: an underflow means a reservation was
    /// returned twice (the accounting bug this guards against), which
    /// must fail loudly rather than silently hand out phantom pages.
    pub fn return_reservation(&mut self, seq: &ActiveSeq) {
        self.reserved_pages = self
            .reserved_pages
            .checked_sub(seq.reserved_pages)
            .unwrap_or_else(|| {
                panic!(
                    "page-reservation underflow: returning {} pages with only {} reserved \
                     (request {} returned its reservation twice)",
                    seq.reserved_pages, self.reserved_pages, seq.id
                )
            });
        // Release the prefix-cache borrow alongside the reservation —
        // the entry becomes LRU-evictable again.
        if let (Some(px), Some((entry, _))) = (self.prefix.as_mut(), seq.prefix) {
            px.release(entry);
        }
    }
}

/// Find or create the group for `spec_raw` in `groups`; returns its
/// index (a stable key while no groups are removed — they never are).
pub(crate) fn group_index(
    groups: &mut Vec<EngineGroup>,
    spec_raw: &str,
    cfg: &ServeConfig,
) -> Result<usize, ServeError> {
    let canon = parse_spec(spec_raw)?.canonical();
    if let Some(i) = groups.iter().position(|g| g.spec == canon) {
        return Ok(i);
    }
    let scfg =
        SessionConfig::new(0, cfg.heads, cfg.d, cfg.d).with_paging(cfg.page_size, cfg.max_pages);
    let session = AttentionSession::from_spec(&canon, scfg)?;
    let prefix = cfg.prefix_cache.map(|px| {
        RadixPrefixCache::new(cfg.heads, cfg.page_size, px.max_pages.min(cfg.max_pages))
    });
    let draft = match &cfg.speculate {
        Some(sp) => Some(AttentionSession::from_spec(&sp.draft.canonical(), scfg)?),
        None => None,
    };
    groups.push(EngineGroup {
        spec: canon,
        session,
        active: Vec::new(),
        reserved_pages: 0,
        prefix,
        draft,
    });
    Ok(groups.len() - 1)
}

/// Prefill one admitted request into `group` at its own boundary and
/// sample its first token. On failure the lane is gone (`prefill_lane`
/// / `extend_lane` auto-release) and the request is handed back with
/// the error.
///
/// With `prefix: Some(hit)` the lane is seeded by forking the cached
/// prefix at `hit.shared` tokens, and only the prompt *suffix* is
/// stored and engine-prefilled. The first token is always sampled from
/// [`AttentionSession::lane_last_output`] — the cache-scored output of
/// the final prompt position — which reads only cache bytes; since a
/// hit lane's cache bytes equal a cold prefill's exactly, greedy
/// streams are **bit-for-bit identical** with the prefix cache on,
/// off, hit, or missed. (The caller's borrow bookkeeping happens after
/// this returns; a failed start leaves nothing to unwind here.)
///
/// Under chunked prefill (`cfg.prefill_chunk > 0`) this only *claims*
/// the lane (forking any cached prefix) and returns a sequence with
/// `prefill: Some(..)` — prompt ingestion and the first-token sample
/// happen chunk-by-chunk in [`ContinuousBatcher::step`]'s prefill
/// pass, so admission never stalls live decode lanes on a long prompt.
pub(crate) fn start_seq(
    model: &ToyLm,
    group: &mut EngineGroup,
    id: RequestId,
    req: ServeRequest,
    submitted: Instant,
    cfg: &ServeConfig,
    reserved_pages: usize,
    prefix: Option<&PrefixHit>,
) -> Result<ActiveSeq, (ServeRequest, ServeError)> {
    let plen = req.prompt.len();
    let budget = req.max_new.min(cfg.max_seq - plen);
    if cfg.prefill_chunk > 0 {
        // Chunked admission: claim the lane now, ingest the prompt in
        // the scheduler's per-step chunk pass. A prefix hit starts
        // with the shared tokens already consumed (`peek` caps shared
        // at plen - 1, so at least one suffix chunk always follows).
        let (lane, consumed) = match prefix {
            Some(hit) => {
                debug_assert!(cfg.kv_policy.is_none(), "prefix cache runs policy-free");
                match group.session.admit_lane_from_fork(&hit.seqs, hit.shared) {
                    Ok(l) => (l, hit.shared),
                    Err(e) => return Err((req, e.into())),
                }
            }
            None => {
                let lane = match &cfg.kv_policy {
                    Some(p) => group.session.admit_lane_with_policy(p),
                    None => group.session.admit_lane(),
                };
                (lane, 0)
            }
        };
        let rng = Rng::new(cfg.model_seed ^ req.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let now = Instant::now();
        group.reserved_pages += reserved_pages;
        return Ok(ActiveSeq {
            id,
            req,
            lane,
            last_token: 0,
            generated: Vec::new(),
            budget,
            reserved_pages,
            prefix: prefix.map(|h| (h.entry, h.shared)),
            rng,
            submitted,
            last_token_at: now,
            ttft_s: 0.0,
            done: None,
            prefill: Some(PrefillState { consumed, total: plen }),
            draft_lane: None,
        });
    }
    let (q, k, v) = model.qkv_prompt(&req.prompt, 0);
    // Policy-budget serving admits every lane with its eviction
    // policy; prefill_lane prunes a long prompt back under the budget
    // before this call returns, so the reservation accounting below
    // only ever has to cover the pruned steady state.
    let lane = match prefix {
        Some(hit) => {
            debug_assert!(cfg.kv_policy.is_none(), "prefix cache runs policy-free");
            let lane = match group.session.admit_lane_from_fork(&hit.seqs, hit.shared) {
                Ok(l) => l,
                Err(e) => return Err((req, e.into())),
            };
            // Store only the suffix KV (bit-identical payloads to a
            // cold prefill of the same tokens) ...
            let ks = k.slice_rows(hit.shared, plen);
            let vs = v.slice_rows(hit.shared, plen);
            if let Err(e) = group.session.extend_lane(lane, &ks, &vs) {
                return Err((req, e.into()));
            }
            // ... and pay the chunked-prefill compute: every suffix
            // query attends the cached prefix plus its causal suffix
            // predecessors. For SFA specs this runs the tiled
            // block-skipping append kernel; dense keeps the per-token
            // loop. Outputs are discarded either way — the first token
            // is sampled below from `lane_last_output`, so greedy
            // streams are bit-for-bit independent of which kernel ran.
            let qs = q.slice_rows(hit.shared, plen);
            let _ = group.session.chunked_prefill_outputs(lane, &qs, hit.shared);
            lane
        }
        None => {
            let lane = match &cfg.kv_policy {
                Some(p) => group.session.admit_lane_with_policy(p),
                None => group.session.admit_lane(),
            };
            if let Err(e) = group.session.prefill_lane(lane, &q, &k, &v, true) {
                return Err((req, e.into()));
            }
            lane
        }
    };
    // First token: the cache-scored output at the last prompt position
    // — one computation for every lane kind, which is what makes the
    // greedy-stream pins structural rather than numerical: a prefix
    // hit's cache bytes equal a cold prefill's (on/off/hit/miss
    // bitwise-identical streams), and a no-op-budget policy lane's
    // cache equals a plain lane's (the PR-4 no-op guarantee). For a
    // *pruning* policy lane this is a deliberate semantic change from
    // PR 4: the first token now reads the policy-pruned cache, so
    // eviction error applies uniformly from the first sampled token
    // instead of starting at the second.
    let out = group.session.lane_last_output(lane, &q.slice_rows(plen - 1, plen));
    let logits = model.logits_at(&out, 0, 0);
    let mut rng = Rng::new(cfg.model_seed ^ req.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let tok = sample(&logits, req.sampling, &mut rng);
    let now = Instant::now();
    group.reserved_pages += reserved_pages;
    Ok(ActiveSeq {
        id,
        req,
        lane,
        last_token: tok,
        generated: vec![tok],
        budget,
        reserved_pages,
        prefix: prefix.map(|h| (h.entry, h.shared)),
        rng,
        submitted,
        last_token_at: now,
        ttft_s: now.duration_since(submitted).as_secs_f64(),
        done: None,
        prefill: None,
        draft_lane: None,
    })
}

/// Has this sequence just finished, and why?
pub(crate) fn finish_reason(seq: &ActiveSeq) -> Option<FinishReason> {
    let last = *seq.generated.last().expect("active sequence has at least one token");
    if seq.req.stop_tokens.contains(&last) {
        return Some(FinishReason::StopToken);
    }
    if seq.generated.len() >= seq.budget {
        return Some(if seq.budget < seq.req.max_new {
            FinishReason::ContextFull
        } else {
            FinishReason::MaxTokens
        });
    }
    None
}

/// Terminal summary for a sequence (total latency measured now — for
/// wave scheduling that is wave-end, the moment the old API delivered).
pub(crate) fn finished_record(
    seq: &ActiveSeq,
    spec: &str,
    state: RequestState,
) -> FinishedRequest {
    FinishedRequest {
        id: seq.id,
        engine: spec.to_string(),
        prompt_len: seq.req.prompt.len(),
        tokens: seq.generated.clone(),
        state,
        ttft_s: seq.ttft_s,
        total_s: seq.submitted.elapsed().as_secs_f64(),
        prefix_shared: seq.prefix.map(|(_, shared)| shared).unwrap_or(0),
        slo: seq.req.slo,
    }
}

/// State every serve scheduler carries: the bounded admission queue,
/// engine groups, the lifecycle map, terminal records, and metrics.
/// `ContinuousBatcher` and `WaveScheduler` embed this and differ only
/// in their `step()` policy.
pub(crate) struct SchedulerCore {
    pub cfg: ServeConfig,
    pub model: ToyLm,
    pub queue: VecDeque<QueuedReq>,
    pub groups: Vec<EngineGroup>,
    pub states: BTreeMap<RequestId, RequestState>,
    pub finished: Vec<FinishedRequest>,
    pub metrics: ServeMetrics,
    pub next_id: RequestId,
}

impl SchedulerCore {
    /// Panics on a degenerate config (see `ServeConfig::assert_valid`);
    /// CLI layers should range-check user input first.
    pub fn new(cfg: ServeConfig) -> SchedulerCore {
        cfg.assert_valid();
        SchedulerCore {
            model: ToyLm::new(cfg.heads, cfg.d, cfg.vocab, cfg.model_seed),
            cfg,
            queue: VecDeque::new(),
            groups: Vec::new(),
            states: BTreeMap::new(),
            finished: Vec::new(),
            metrics: ServeMetrics::default(),
            next_id: 0,
        }
    }

    /// Shared `Scheduler::submit` body: validate, enforce the queue
    /// bound, assign an id, record `Queued`, enqueue.
    pub fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError> {
        validate(&req, &self.cfg)?;
        if self.queue.len() >= self.cfg.queue_capacity {
            return Err(ServeError::QueueFull { capacity: self.cfg.queue_capacity });
        }
        let id = self.next_id;
        self.next_id += 1;
        set_state(&mut self.states, &req, id, RequestState::Queued);
        self.queue.push_back(QueuedReq { id, req, submitted: Instant::now() });
        Ok(id)
    }

    pub fn state(&self, id: RequestId) -> Option<&RequestState> {
        self.states.get(&id)
    }

    /// Drain terminal summaries and prune their lifecycle entries, so a
    /// long-running scheduler's state map stays bounded by queued +
    /// live requests instead of growing with every request ever served.
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        let out = std::mem::take(&mut self.finished);
        for f in &out {
            self.states.remove(&f.id);
        }
        out
    }

    pub fn pages_in_use(&self) -> usize {
        self.groups.iter().map(|g| g.session.pages_in_use()).sum()
    }

    /// Half-page units consumed across all engine groups (fp32 page =
    /// 2, int8 = 1) — `2 * pages_in_use()` while nothing is demoted.
    pub fn units_in_use(&self) -> usize {
        self.groups.iter().map(|g| g.session.units_in_use()).sum()
    }

    /// Terminal failure: `Failed` state, empty-token summary, metric.
    pub fn fail_request(&mut self, id: RequestId, req: &ServeRequest, e: ServeError) {
        set_state(&mut self.states, req, id, RequestState::Failed { error: e.clone() });
        self.finished.push(FinishedRequest {
            id,
            engine: req.engine.clone(),
            prompt_len: req.prompt.len(),
            tokens: Vec::new(),
            state: RequestState::Failed { error: e },
            ttft_s: 0.0,
            total_s: 0.0,
            prefix_shared: 0,
            slo: req.slo,
        });
        self.metrics.record_failed();
    }
}

/// Continuous batching: sequences join a live decode wave at their own
/// prefill boundary and leave (freeing pages) the step they finish.
pub struct ContinuousBatcher {
    core: SchedulerCore,
}

impl ContinuousBatcher {
    /// Panics on a degenerate config (see `ServeConfig::assert_valid`);
    /// CLI layers should range-check user input first.
    pub fn new(cfg: ServeConfig) -> ContinuousBatcher {
        ContinuousBatcher { core: SchedulerCore::new(cfg) }
    }

    /// Checked constructor: validates first and hands back the typed
    /// [`ServeConfigError`] instead of panicking — the CLI-facing path
    /// (pair with [`ServeConfig::builder`]).
    pub fn try_new(cfg: ServeConfig) -> Result<ContinuousBatcher, ServeConfigError> {
        cfg.validate()?;
        Ok(ContinuousBatcher::new(cfg))
    }

    pub fn config(&self) -> &ServeConfig {
        &self.core.cfg
    }

    /// Live sequences across all groups.
    pub fn live(&self) -> usize {
        self.core.groups.iter().map(|g| g.active.len()).sum()
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.core.queue.len()
    }

    /// Peek a still-queued request by id — `None` once admission has
    /// claimed it (or it never queued here). The router's re-routing
    /// pass reads this to re-score a waiting request without touching
    /// queue order.
    pub fn queued_request(&self, id: RequestId) -> Option<&ServeRequest> {
        self.core.queue.iter().find(|q| q.id == id).map(|q| &q.req)
    }

    /// Withdraw a still-queued request — the admission-time re-routing
    /// primitive: a request that has not started prefill holds no
    /// lane, pages, reservation, or prefix borrow, so removing it is
    /// pure queue surgery and the request can be resubmitted elsewhere
    /// with an identical stream (samplers derive from `(model_seed,
    /// req.seed)`, never from placement). Returns `None` if the id is
    /// not queued here (already admitted, finished, or unknown) — the
    /// caller must treat that as "too late to migrate".
    pub fn withdraw(&mut self, id: RequestId) -> Option<ServeRequest> {
        let at = self.core.queue.iter().position(|q| q.id == id)?;
        let qr = self.core.queue.remove(at).expect("position came from this queue");
        self.core.states.remove(&id);
        Some(qr.req)
    }

    /// Worst tier round-trip error observed by any engine group, as a
    /// ratio of the per-row int8 bound (`scale/2` per element): ≤ 1.0
    /// means every demoted page stayed within the quantizer's contract.
    /// 0.0 until a demotion happens — the bench gate's accuracy probe.
    pub fn tier_max_error_ratio(&self) -> f32 {
        self.core
            .groups
            .iter()
            .map(|g| g.session.tier_max_error_ratio())
            .fold(0.0, f32::max)
    }

    /// Longest cached prompt prefix (in tokens) across this batcher's
    /// engine groups — the router's cross-replica affinity probe.
    /// Read-only: walks the radix tries without touching LRU order,
    /// borrows, or hit/miss stats, so probing N replicas before every
    /// routing decision never perturbs any replica's admission
    /// behaviour or blocks its step loop. Zero without a prefix cache
    /// (or before the first admission creates the engine group).
    pub fn prefix_probe(&self, prompt: &[i32]) -> usize {
        self.core
            .groups
            .iter()
            .filter_map(|g| g.prefix.as_ref().map(|px| px.longest_prefix(prompt)))
            .max()
            .unwrap_or(0)
    }

    /// Preempt the newest-admitted batch-class sequence — optionally
    /// restricted to one engine group, for page pressure (page budgets
    /// are per group; the lane cap is global) — to make room for an
    /// interactive admission. Returns `false` when no batch lane is
    /// live (interactive lanes are never preempted).
    ///
    /// Restart semantics: the victim's lane, draft lane, reservation,
    /// and prefix borrow are released (nothing is checkpointed), its
    /// generated tokens are discarded, and the request re-queues at its
    /// class-front position with its **original** submission time. The
    /// per-request sampler rng is re-derived from `(model_seed,
    /// req.seed)` at re-admission and the [`ToyLm`] is batch-composition
    /// independent, so the restarted lane regenerates the identical
    /// token stream — consumers observing the event channel see `State:
    /// Queued` followed by a replay of the same `Token { index: 0.. }`
    /// events, and the terminal [`FinishedRequest::tokens`] is
    /// bit-for-bit what a never-preempted run produces.
    fn preempt_batch_lane(&mut self, group: Option<usize>, report: &mut StepReport) -> bool {
        let mut victim: Option<(usize, usize, RequestId)> = None;
        for (gi, g) in self.core.groups.iter().enumerate() {
            if group.map_or(false, |want| want != gi) {
                continue;
            }
            for (ai, seq) in g.active.iter().enumerate() {
                if seq.req.slo.is_interactive() {
                    continue;
                }
                if victim.map_or(true, |(_, _, vid)| seq.id > vid) {
                    victim = Some((gi, ai, seq.id));
                }
            }
        }
        let Some((gi, ai, _)) = victim else {
            return false;
        };
        let seq = self.core.groups[gi].active.swap_remove(ai);
        let g = &mut self.core.groups[gi];
        if let (Some(dl), Some(draft)) = (seq.draft_lane, g.draft.as_mut()) {
            let _ = draft.release_lane(dl);
        }
        let freed = g.session.release_lane(seq.lane).unwrap_or(0);
        g.return_reservation(&seq);
        report.pages_freed += freed;
        report.preempted += 1;
        set_state(&mut self.core.states, &seq.req, seq.id, RequestState::Queued);
        // Re-queue at the batch-class front (behind every queued
        // interactive request, ahead of batch requests that were never
        // admitted — the victim is older than all of them).
        let at = self
            .core
            .queue
            .iter()
            .position(|q| !q.req.slo.is_interactive())
            .unwrap_or(self.core.queue.len());
        self.core
            .queue
            .insert(at, QueuedReq { id: seq.id, req: seq.req, submitted: seq.submitted });
        true
    }

    /// Admission pass: fill free lanes from the queue under the page
    /// budget. FIFO with head-of-line blocking on a not-yet-fitting
    /// request — within an SLO class: interactive requests are
    /// considered before batch requests (stable within each class, so
    /// a single-class queue is plain FIFO and this is exactly the
    /// legacy policy), and an interactive request blocked on lanes or
    /// pages may preempt batch lanes ([`Self::preempt_batch_lane`])
    /// before giving up and waiting. With a prefix cache, the longest
    /// cached prompt prefix is looked up first: a hit reserves only
    /// the un-shared suffix ([`pages_reserved_shared`]), and admission
    /// pressure evicts LRU prefix entries (never the entry about to be
    /// used) before giving up and waiting.
    fn admit(&mut self, report: &mut StepReport) {
        if self.core.queue.iter().any(|q| q.req.slo.is_interactive())
            && self.core.queue.iter().any(|q| !q.req.slo.is_interactive())
        {
            let (hi, lo): (Vec<QueuedReq>, Vec<QueuedReq>) =
                self.core.queue.drain(..).partition(|q| q.req.slo.is_interactive());
            self.core.queue.extend(hi);
            self.core.queue.extend(lo);
        }
        while let Some(front) = self.core.queue.front() {
            let interactive = front.req.slo.is_interactive();
            if self.live() >= self.core.cfg.max_lanes {
                // The lane cap is global — interactive pressure may
                // preempt the newest batch lane of any group.
                if interactive && self.preempt_batch_lane(None, report) {
                    continue;
                }
                break;
            }
            let gi = match group_index(&mut self.core.groups, &front.req.engine, &self.core.cfg)
            {
                Ok(gi) => gi,
                Err(e) => {
                    // Spec parsed at submit but the session rejected it
                    // (e.g. feature budget k > head dim d).
                    let qr = self.core.queue.pop_front().expect("front exists");
                    self.core.fail_request(qr.id, &qr.req, e);
                    report.failed += 1;
                    continue;
                }
            };
            let plen = front.req.prompt.len();
            let budget_tokens = front.req.max_new.min(self.core.cfg.max_seq - plen);
            let hit = self.core.groups[gi]
                .prefix
                .as_ref()
                .and_then(|px| px.peek(&front.req.prompt));
            // Tiered admission charges the compressed steady state —
            // the concurrency lever: more lanes per fixed max_pages.
            // With kv_tier off this is exactly the legacy reservation.
            let shared_tokens = hit.as_ref().map(|h| h.shared).unwrap_or(0);
            let needed =
                pages_reserved_tiered(plen, budget_tokens, shared_tokens, &self.core.cfg);
            // Fit check, counting the prefix cache's nominal footprint
            // against the same budget; evict LRU entries under
            // pressure (never the entry about to be used).
            let fits = loop {
                let g = &mut self.core.groups[gi];
                let nominal = g.prefix.as_ref().map(|p| p.pages_nominal()).unwrap_or(0);
                if g.reserved_pages + nominal + needed <= self.core.cfg.max_pages {
                    break true;
                }
                let exclude = hit.as_ref().map(|h| h.entry);
                let evicted = match g.prefix.as_mut() {
                    Some(px) => px.evict_lru(g.session.cache_mut(), exclude),
                    None => false,
                };
                if !evicted {
                    break false;
                }
            };
            if !fits {
                // Page budgets are per group — only preempting one of
                // *this* group's batch lanes can free the pages.
                if interactive && self.preempt_batch_lane(Some(gi), report) {
                    continue;
                }
                break; // wait for pages to drain
            }
            if self.core.cfg.kv_policy.is_some() || self.core.cfg.kv_tier.is_some() {
                // Transient check: the whole prompt is paged in during
                // prefill — at full fp32 width, before the post-prefill
                // prune (kv_policy) or the post-decode demotion pass
                // (kv_tier) shrinks it to the reservation. Live lanes
                // never exceed their own reservations, so the
                // instantaneously free pool is a safe bound; the
                // transient resolves inside this same admission pass
                // (policy) or by the next step's demotion (tier).
                let transient =
                    pages_needed(plen, 0, self.core.cfg.heads, self.core.cfg.page_size);
                if transient > self.core.groups[gi].session.pages_free() {
                    if interactive && self.preempt_batch_lane(Some(gi), report) {
                        continue;
                    }
                    break; // wait for pages to drain
                }
            }
            let QueuedReq { id, req, submitted } =
                self.core.queue.pop_front().expect("front exists");
            let shared = hit.as_ref().map(|h| h.shared).unwrap_or(0);
            set_state(
                &mut self.core.states,
                &req,
                id,
                RequestState::Prefilling { consumed: shared, total: plen },
            );
            let seq = match start_seq(
                &self.core.model,
                &mut self.core.groups[gi],
                id,
                req,
                submitted,
                &self.core.cfg,
                needed,
                hit.as_ref(),
            ) {
                Ok(seq) => seq,
                Err((req, e)) => {
                    self.core.fail_request(id, &req, e);
                    report.failed += 1;
                    continue;
                }
            };
            // Prefix bookkeeping only once the lane actually started:
            // a hit pins its entry against LRU eviction for the lane's
            // lifetime (the shared pages back this lane's suffix-only
            // reservation).
            let g = &mut self.core.groups[gi];
            if let Some(px) = g.prefix.as_mut() {
                match &hit {
                    Some(h) => {
                        // Borrowing promotes a pressure-demoted entry
                        // back to f32 (the lane reads it hot).
                        px.borrow(h.entry, g.session.cache_mut());
                        report.prefix_hits += 1;
                    }
                    None => px.note_miss(),
                }
            }
            report.admitted += 1;
            if seq.prefill.is_some() {
                // Chunked mode: the lane is claimed but the prompt is
                // not ingested yet — the chunk pass (same step) does
                // that, and samples the TTFT token when it completes.
                self.core.groups[gi].active.push(seq);
                continue;
            }
            report.decoded_tokens += 1; // the TTFT token
            set_state(&mut self.core.states, &seq.req, id, RequestState::Decoding);
            emit(&seq.req, ServeEvent::Token { id, index: 0, token: seq.last_token });
            if let Some(reason) = finish_reason(&seq) {
                self.retire(gi, seq, reason, report);
            } else {
                self.core.groups[gi].active.push(seq);
            }
        }
    }

    /// Release a finished sequence's lane and record its summary — on
    /// the same step it finished (the scheduler-invariant the tests
    /// pin). With a prefix cache, the request's prompt path is
    /// inserted first (forking the lane's prefix shares pages — no
    /// copy), then the lane's own pages are freed and its reservation
    /// (and prefix borrow) returned exactly once.
    fn retire(&mut self, gi: usize, seq: ActiveSeq, reason: FinishReason, report: &mut StepReport) {
        let group = &mut self.core.groups[gi];
        if let Some(px) = group.prefix.as_mut() {
            let seqs = group.session.lane_seqs(seq.lane).to_vec();
            px.insert(&seq.req.prompt, group.session.cache_mut(), &seqs);
        }
        // The draft lane's pages live in the draft session's own pool;
        // they are freed here and never show in the target accounting.
        if let (Some(dl), Some(draft)) = (seq.draft_lane, group.draft.as_mut()) {
            let _ = draft.release_lane(dl);
        }
        let freed = group.session.release_lane(seq.lane).unwrap_or(0);
        group.return_reservation(&seq);
        report.pages_freed += freed;
        report.finished += 1;
        let state = RequestState::Finished { reason };
        set_state(&mut self.core.states, &seq.req, seq.id, state.clone());
        self.core.metrics.record_finished(
            seq.ttft_s,
            seq.submitted.elapsed().as_secs_f64(),
            seq.generated.len(),
        );
        self.core.finished.push(finished_record(&seq, &self.core.groups[gi].spec, state));
    }

    /// Chunked-prefill pass (`ServeConfig::prefill_chunk > 0`): every
    /// lane still ingesting its prompt advances by up to one chunk of
    /// prompt tokens, then lanes whose prefill just completed sample
    /// their first token and join this same step's decode wave. The
    /// budget is **per lane**, not shared across lanes: a short prompt
    /// admitted behind a half-ingested 4096-token prompt finishes its
    /// own prefill in its first step — the decode-lane TTFT win `sfa
    /// bench serve --prefill-chunk` measures.
    ///
    /// Chunk attention outputs are discarded; the first token is
    /// sampled from [`AttentionSession::lane_last_output`] with the
    /// regenerated last-position query row ([`ToyLm`] rows are pure
    /// functions of (token, position)), reading only cache bytes — the
    /// same computation as the monolithic path, so greedy streams are
    /// bit-for-bit chunk-size-invariant.
    fn advance_prefills(&mut self, report: &mut StepReport) {
        let chunk = self.core.cfg.prefill_chunk;
        if chunk == 0 {
            return;
        }
        for gi in 0..self.core.groups.len() {
            let mut i = 0;
            while i < self.core.groups[gi].active.len() {
                let Some(st) = self.core.groups[gi].active[i].prefill else {
                    i += 1;
                    continue;
                };
                let take = chunk.min(st.total - st.consumed);
                let (id, lane) = {
                    let seq = &self.core.groups[gi].active[i];
                    (seq.id, seq.lane)
                };
                let (q, k, v) = self.core.model.qkv_prompt(
                    &self.core.groups[gi].active[i].req.prompt[st.consumed..st.consumed + take],
                    st.consumed,
                );
                if let Err(e) =
                    self.core.groups[gi].session.prefill_chunk(lane, &q, &k, &v, st.total)
                {
                    // The session auto-released the lane; drop the
                    // sequence and return its reservation (and prefix
                    // borrow) exactly once.
                    let seq = self.core.groups[gi].active.swap_remove(i);
                    self.core.groups[gi].return_reservation(&seq);
                    self.core.fail_request(id, &seq.req, ServeError::from(e));
                    report.failed += 1;
                    continue; // i now holds the swapped-in element
                }
                report.prefill_tokens += take;
                let consumed = st.consumed + take;
                if consumed < st.total {
                    self.core.groups[gi].active[i].prefill =
                        Some(PrefillState { consumed, total: st.total });
                    set_state(
                        &mut self.core.states,
                        &self.core.groups[gi].active[i].req,
                        id,
                        RequestState::Prefilling { consumed, total: st.total },
                    );
                    i += 1;
                    continue;
                }
                // Prompt fully cached: sample the TTFT token from the
                // cache-scored output at the last prompt position.
                let (ql, _, _) = {
                    let prompt = &self.core.groups[gi].active[i].req.prompt;
                    self.core.model.qkv_prompt(&prompt[st.total - 1..], st.total - 1)
                };
                let out = self.core.groups[gi].session.lane_last_output(lane, &ql);
                let logits = self.core.model.logits_at(&out, 0, 0);
                let now = Instant::now();
                {
                    let seq = &mut self.core.groups[gi].active[i];
                    let tok = sample(&logits, seq.req.sampling, &mut seq.rng);
                    seq.prefill = None;
                    seq.last_token = tok;
                    seq.generated.push(tok);
                    seq.last_token_at = now;
                    seq.ttft_s = now.duration_since(seq.submitted).as_secs_f64();
                }
                report.decoded_tokens += 1; // the TTFT token
                set_state(
                    &mut self.core.states,
                    &self.core.groups[gi].active[i].req,
                    id,
                    RequestState::Decoding,
                );
                let seq = &self.core.groups[gi].active[i];
                emit(&seq.req, ServeEvent::Token { id, index: 0, token: seq.last_token });
                if let Some(reason) = finish_reason(seq) {
                    let seq = self.core.groups[gi].active.swap_remove(i);
                    self.retire(gi, seq, reason, report);
                    continue;
                }
                i += 1;
            }
        }
    }

    /// One speculative draft-and-verify step for a single lane.
    ///
    /// 1. **Draft.** The lane's draft-session lane (lazily seeded with
    ///    the stream prefix the target lane has cached) proposes
    ///    `γ_eff = min(γ, budget_remaining − 1)` tokens by greedy
    ///    argmax. Greedy draws nothing from any rng, so the request's
    ///    sampler stream is untouched no matter how far the draft runs.
    /// 2. **Verify.** The target scores all γ_eff+1 positions in one
    ///    [`AttentionSession::score_lanes`] forward on a
    ///    `fork_prefix`-forked lane — the fork is the scratch space;
    ///    rollback is `release_lane` on it, so the real lane's paged
    ///    accounting never sees the speculation.
    /// 3. **Emit.** [`verify_emit`] replays exactly the `sample` calls
    ///    sequential decoding would make (the exact-match acceptance
    ///    rule — see the `speculate` module docs), emissions are
    ///    truncated at the first stop token, and the committed stream
    ///    prefix's K/V rows are appended to the real lane.
    /// 4. **Reconcile.** The draft lane is shrunk (re-forked at the
    ///    agreed prefix) after a rejection or extended with the bonus
    ///    row after a full accept, ready for the next step.
    ///
    /// Every failure path inside speculation degrades to
    /// [`SpecOutcome::Fallback`] — the lane decodes plainly this step —
    /// except a real-lane `extend_lane` failure, which is
    /// [`SpecOutcome::Fatal`] (the lane is auto-released; unreachable
    /// under reservation accounting since the committed rows are within
    /// the sequence's reserved footprint).
    fn speculate_lane(&mut self, gi: usize, ai: usize, report: &mut StepReport) -> SpecOutcome {
        let sp = self.core.cfg.speculate.expect("speculate_lane requires ServeConfig::speculate");
        let heads = self.core.cfg.heads;
        let d = self.core.cfg.d;
        let (lane, last_token, remaining) = {
            let seq = &self.core.groups[gi].active[ai];
            (seq.lane, seq.last_token, seq.budget - seq.generated.len())
        };
        // With one token of budget left nothing past the correction
        // could ever be committed — plain decode is strictly cheaper.
        if remaining < 2 {
            return SpecOutcome::Fallback;
        }
        let gamma = sp.gamma.min(remaining - 1);
        let p = self.core.groups[gi].session.lane_len(lane);

        // Draft lane: reuse if it mirrors the target's cached prefix,
        // otherwise drop and re-seed (a stale length can only follow a
        // fallback path that already advanced the target without it).
        let mut dl = match self.core.groups[gi].active[ai].draft_lane {
            Some(l)
                if self
                    .core
                    .groups[gi]
                    .draft
                    .as_ref()
                    .expect("draft lane implies draft session")
                    .lane_len(l)
                    == p =>
            {
                Some(l)
            }
            Some(l) => {
                let draft =
                    self.core.groups[gi].draft.as_mut().expect("draft lane implies draft session");
                let _ = draft.release_lane(l);
                None
            }
            None => None,
        };
        if dl.is_none() {
            // Seed with the stream prefix the target lane has cached:
            // prompt ++ generated[..len-1] (the last sampled token is
            // never cached — the decode-state invariant). ToyLm rows
            // are pure functions of (token, position), so a monolithic
            // prefill reproduces what incremental drafting would have.
            let stream: Vec<i32> = {
                let seq = &self.core.groups[gi].active[ai];
                let gen = &seq.generated[..seq.generated.len() - 1];
                seq.req.prompt.iter().chain(gen.iter()).copied().collect()
            };
            debug_assert_eq!(stream.len(), p, "target lane caches exactly the stream prefix");
            let (q, k, v) = self.core.model.qkv_prompt(&stream, 0);
            let draft = self.core.groups[gi].draft.as_mut().expect("speculation is on");
            let new_dl = draft.admit_lane();
            match draft.prefill_lane(new_dl, &q, &k, &v, true) {
                Ok(_) => dl = Some(new_dl),
                // Draft pool out of pages (the lane auto-released):
                // decode plainly, retry seeding once pages drain.
                Err(_) => {
                    self.core.groups[gi].active[ai].draft_lane = None;
                    return SpecOutcome::Fallback;
                }
            }
        }
        let dl = dl.expect("seeded above");
        self.core.groups[gi].active[ai].draft_lane = Some(dl);

        // -- 1. Draft proposes γ tokens by greedy argmax. -------------
        let mut scratch = Rng::new(0); // greedy sample() draws nothing
        let mut candidates: Vec<i32> = Vec::with_capacity(gamma);
        let mut tok = last_token;
        for j in 0..gamma {
            let mut q1 = HeadTensor::zeros(1, heads, 1, d);
            let mut k1 = HeadTensor::zeros(1, heads, 1, d);
            let mut v1 = HeadTensor::zeros(1, heads, 1, d);
            self.core.model.fill_decode_row(&mut q1, &mut k1, &mut v1, 0, tok, p + j);
            let draft = self.core.groups[gi].draft.as_mut().expect("speculation is on");
            let out = match draft.decode_step_lanes(&[dl], &q1, &k1, &v1) {
                Ok(o) => o,
                Err(_) => {
                    // decode_step_lanes does not auto-release; drop the
                    // half-advanced draft lane and fall back.
                    let _ = draft.release_lane(dl);
                    self.core.groups[gi].active[ai].draft_lane = None;
                    return SpecOutcome::Fallback;
                }
            };
            let logits = self.core.model.logits_at(&out, 0, 0);
            tok = sample(&logits, ServeSampling::Greedy, &mut scratch);
            candidates.push(tok);
        }

        // -- 2. Target verifies all γ+1 positions in one forward. -----
        // verify_tokens is the stream continuation *if* every candidate
        // is accepted: S[p] (= last_token, K/V not yet cached) followed
        // by the draft's proposals, at positions p..p+γ+1.
        let mut verify_tokens = Vec::with_capacity(gamma + 1);
        verify_tokens.push(last_token);
        verify_tokens.extend_from_slice(&candidates);
        let (vq, vk, vv) = self.core.model.qkv_prompt(&verify_tokens, p);
        let src = self.core.groups[gi].session.lane_seqs(lane).to_vec();
        let fork = match self.core.groups[gi].session.admit_lane_from_fork(&src, p) {
            Ok(f) => f,
            Err(_) => {
                // The draft already advanced γ rows the target won't
                // match this step — drop it and re-seed next step.
                let draft = self.core.groups[gi].draft.as_mut().expect("speculation is on");
                let _ = draft.release_lane(dl);
                self.core.groups[gi].active[ai].draft_lane = None;
                return SpecOutcome::Fallback;
            }
        };
        let out = match self.core.groups[gi].session.score_lanes(&[fork], &vq, &vk, &vv) {
            Ok(o) => o,
            Err(_) => {
                // score_lanes auto-released the fork (mid-step
                // OutOfPages during verify); same staleness cleanup.
                let draft = self.core.groups[gi].draft.as_mut().expect("speculation is on");
                let _ = draft.release_lane(dl);
                self.core.groups[gi].active[ai].draft_lane = None;
                return SpecOutcome::Fallback;
            }
        };
        // Rollback: the fork (and the γ+1 rows just appended to it) is
        // scratch — the real lane still holds exactly p tokens.
        let _ = self.core.groups[gi].session.release_lane(fork);

        // -- 3. Emit under the exact-match acceptance rule. -----------
        let logits: Vec<Vec<f32>> =
            (0..gamma + 1).map(|t| self.core.model.logits_at(&out, 0, t)).collect();
        let emitted = {
            let seq = &mut self.core.groups[gi].active[ai];
            verify_emit(&candidates, &logits, seq.req.sampling, &mut seq.rng)
        };
        // Truncate at the first stop token: sequential decoding would
        // have stopped sampling there. (verify_emit's extra rng draws
        // past it are harmless — the request finishes and its rng is
        // never consulted again.)
        let m_e = {
            let stop = &self.core.groups[gi].active[ai].req.stop_tokens;
            match emitted.iter().position(|t| stop.contains(t)) {
                Some(i) => i + 1,
                None => emitted.len(),
            }
        };
        report.spec_accepted += m_e - 1;
        self.core.metrics.record_speculation(gamma, m_e - 1);
        self.core.metrics.record_decode(m_e);

        // Commit the accepted stream prefix's K/V: rows 0..m_e of the
        // verify tensors are exactly S[p..p+m_e], bit-identical to what
        // m_e sequential decode steps would have appended.
        if let Err(e) = self
            .core
            .groups[gi]
            .session
            .extend_lane(lane, &vk.slice_rows(0, m_e), &vv.slice_rows(0, m_e))
        {
            // extend_lane auto-released the lane. Unreachable under
            // reservation accounting — the committed rows fit the
            // sequence's reserved worst-case footprint — so surface it
            // as a request failure, not a panic. The removal pass
            // releases the draft lane and returns the reservation.
            return SpecOutcome::Fatal(ServeError::from(e));
        }
        let now = Instant::now();
        let mut finish = None;
        for &tok in &emitted[..m_e] {
            let seq = &mut self.core.groups[gi].active[ai];
            seq.last_token = tok;
            seq.generated.push(tok);
            emit(
                &seq.req,
                ServeEvent::Token { id: seq.id, index: seq.generated.len() - 1, token: tok },
            );
            self.core
                .metrics
                .record_token_latency(now.duration_since(seq.last_token_at).as_secs_f64());
            // The first emission pays the real inter-step gap; the rest
            // of the batch landed in the same instant.
            self.core.groups[gi].active[ai].last_token_at = now;
            report.decoded_tokens += 1;
            finish = finish_reason(&self.core.groups[gi].active[ai]);
        }
        if finish.is_some() {
            // retire() (run by the caller's removal pass) releases the
            // draft lane alongside the target lane.
            return SpecOutcome::Done(finish);
        }

        // -- 4. Reconcile the draft lane with the committed stream. ---
        let target_len = p + m_e;
        let group = &mut self.core.groups[gi];
        let draft = group.draft.as_mut().expect("speculation is on");
        let dlen = draft.lane_len(dl);
        debug_assert_eq!(dlen, p + gamma, "draft advanced exactly γ rows");
        let new_dl = if target_len < dlen {
            // A rejection: draft rows past the agreed prefix follow a
            // divergent continuation. Shrink by forking the prefix
            // (shares pages, allocates nothing) and dropping the stale
            // lane.
            let dsrc = draft.lane_seqs(dl).to_vec();
            let forked = draft.admit_lane_from_fork(&dsrc, target_len);
            let _ = draft.release_lane(dl);
            forked.ok()
        } else if target_len == dlen {
            // Accepted exactly the rows the draft holds — nothing to do.
            Some(dl)
        } else {
            // Full accept + bonus: the draft is one row short — append
            // the bonus token's K/V (row γ of the verify tensors, the
            // same bytes a draft decode step would have pushed).
            match draft.extend_lane(dl, &vk.slice_rows(gamma, gamma + 1), &vv.slice_rows(gamma, gamma + 1))
            {
                Ok(()) => Some(dl),
                Err(_) => None, // auto-released; re-seed next step
            }
        };
        group.active[ai].draft_lane = new_dl;
        SpecOutcome::Done(None)
    }

    /// One mixed decode step per engine group over all its live lanes
    /// whose prefill is complete (mid-prefill lanes are skipped — they
    /// have no sampled token to extend yet).
    ///
    /// With `ServeConfig::speculate` set, every eligible lane first
    /// attempts a speculative step ([`Self::speculate_lane`]); lanes
    /// that can't speculate right now (budget tail, draft pool out of
    /// pages, verify-fork failure) fall back to the plain batched
    /// single-token path below, so speculation never stalls a stream —
    /// it changes how many tokens a step commits, never which tokens.
    ///
    /// Index iteration is load-bearing: the body calls `&mut self`
    /// methods (retire / fail_request) that an iterator borrow would
    /// forbid. Retirements and failures are collected per active index
    /// and processed once at the end of each group's pass in descending
    /// index order, keeping the pending `swap_remove` targets stable.
    fn decode(&mut self, report: &mut StepReport) {
        for gi in 0..self.core.groups.len() {
            // Batch rows → active indices, skipping mid-prefill lanes.
            let rows: Vec<usize> = (0..self.core.groups[gi].active.len())
                .filter(|&ai| self.core.groups[gi].active[ai].prefill.is_none())
                .collect();
            if rows.is_empty() {
                continue;
            }
            let mut done: Vec<(usize, FinishReason)> = Vec::new();
            let mut failed: Vec<(usize, ServeError)> = Vec::new();
            let mut plain: Vec<usize> = Vec::new();
            if self.core.cfg.speculate.is_some() {
                for &ai in &rows {
                    match self.speculate_lane(gi, ai, report) {
                        SpecOutcome::Done(Some(reason)) => done.push((ai, reason)),
                        SpecOutcome::Done(None) => {}
                        SpecOutcome::Fallback => plain.push(ai),
                        SpecOutcome::Fatal(e) => failed.push((ai, e)),
                    }
                }
            } else {
                plain = rows;
            }
            let n = plain.len();
            if n > 0 {
                let heads = self.core.cfg.heads;
                let d = self.core.cfg.d;
                let mut q = HeadTensor::zeros(n, heads, 1, d);
                let mut k = HeadTensor::zeros(n, heads, 1, d);
                let mut v = HeadTensor::zeros(n, heads, 1, d);
                let mut lanes: Vec<LaneId> = Vec::with_capacity(n);
                for (bi, &ai) in plain.iter().enumerate() {
                    let seq = &self.core.groups[gi].active[ai];
                    let pos = self.core.groups[gi].session.lane_len(seq.lane);
                    self.core
                        .model
                        .fill_decode_row(&mut q, &mut k, &mut v, bi, seq.last_token, pos);
                    lanes.push(seq.lane);
                }
                match self.core.groups[gi].session.decode_step_lanes(&lanes, &q, &k, &v) {
                    Err(e) => {
                        // Unreachable under reservation accounting; fail
                        // this batch defensively rather than panic. The
                        // removal pass below returns each reservation
                        // (and any prefix borrow) exactly once — checked
                        // subtraction in `return_reservation`.
                        for &ai in &plain {
                            let lane = self.core.groups[gi].active[ai].lane;
                            let _ = self.core.groups[gi].session.release_lane(lane);
                            failed.push((ai, ServeError::from(e)));
                        }
                    }
                    Ok(out) => {
                        let now = Instant::now();
                        for (bi, &ai) in plain.iter().enumerate() {
                            let seq = &mut self.core.groups[gi].active[ai];
                            let logits = self.core.model.logits_at(&out, bi, 0);
                            let tok = sample(&logits, seq.req.sampling, &mut seq.rng);
                            seq.last_token = tok;
                            seq.generated.push(tok);
                            emit(
                                &seq.req,
                                ServeEvent::Token {
                                    id: seq.id,
                                    index: seq.generated.len() - 1,
                                    token: tok,
                                },
                            );
                            self.core.metrics.record_token_latency(
                                now.duration_since(seq.last_token_at).as_secs_f64(),
                            );
                            seq.last_token_at = now;
                            self.core.metrics.record_decode(1);
                            report.decoded_tokens += 1;
                            if let Some(reason) = finish_reason(seq) {
                                done.push((ai, reason));
                            }
                        }
                    }
                }
            }
            // Unified removal: descending active index keeps the
            // remaining swap_remove targets stable.
            let mut removals: Vec<(usize, Result<FinishReason, ServeError>)> = done
                .into_iter()
                .map(|(ai, r)| (ai, Ok(r)))
                .chain(failed.into_iter().map(|(ai, e)| (ai, Err(e))))
                .collect();
            removals.sort_by(|a, b| b.0.cmp(&a.0));
            for (ai, outcome) in removals {
                let seq = self.core.groups[gi].active.swap_remove(ai);
                match outcome {
                    Ok(reason) => self.retire(gi, seq, reason, report),
                    Err(e) => {
                        // The target lane is already gone (auto-released
                        // by the failing call, or released above); drop
                        // the draft lane and hand the request back.
                        if let (Some(dl), Some(draft)) =
                            (seq.draft_lane, self.core.groups[gi].draft.as_mut())
                        {
                            let _ = draft.release_lane(dl);
                        }
                        self.core.groups[gi].return_reservation(&seq);
                        self.core.fail_request(seq.id, &seq.req, e);
                        report.failed += 1;
                    }
                }
            }
        }
    }
}

/// Outcome of one [`ContinuousBatcher::speculate_lane`] attempt.
enum SpecOutcome {
    /// The speculative step committed ≥ 1 token; `Some(reason)` if the
    /// sequence finished and must be retired.
    Done(Option<FinishReason>),
    /// Speculation could not run this step — decode the lane plainly
    /// (the stream is unaffected; only the step's token count is).
    Fallback,
    /// The real lane's K/V commit failed (lane auto-released) — fail
    /// the request.
    Fatal(ServeError),
}

impl Scheduler for ContinuousBatcher {
    fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError> {
        self.core.submit(req)
    }

    fn step(&mut self) -> StepReport {
        let mut report = StepReport::default();
        self.admit(&mut report);
        self.advance_prefills(&mut report);
        self.decode(&mut report);
        report.pages_pruned =
            self.core.groups.iter_mut().map(|g| g.session.take_policy_freed()).sum();
        // Tiering pass: after the step's appends, every live lane's
        // cold span demotes to int8 — the budget refund the compressed
        // admission reservation counts on. Counter drain runs even
        // without kv_tier: the radix cache demotes entries under LRU
        // pressure (and promotes on borrow) on its own.
        if let Some(tier) = self.core.cfg.kv_tier {
            for g in &mut self.core.groups {
                g.session.demote_cold(tier);
            }
        }
        for g in &mut self.core.groups {
            let (d, p) = g.session.take_tier_counts();
            report.pages_demoted += d;
            report.pages_promoted += p;
        }
        report.pages_in_use = self.core.pages_in_use();
        report.kv_units_in_use = self.core.units_in_use();
        report.live = self.live();
        report
    }

    fn has_work(&self) -> bool {
        !self.core.queue.is_empty() || self.live() > 0
    }

    fn state(&self, id: RequestId) -> Option<&RequestState> {
        self.core.state(id)
    }

    fn take_finished(&mut self) -> Vec<FinishedRequest> {
        self.core.take_finished()
    }

    fn metrics(&self) -> &ServeMetrics {
        &self.core.metrics
    }

    fn metrics_mut(&mut self) -> &mut ServeMetrics {
        &mut self.core.metrics
    }

    fn pages_in_use(&self) -> usize {
        self.core.pages_in_use()
    }

    fn tier_error_ratio(&self) -> f32 {
        self.tier_max_error_ratio()
    }

    fn prefix_stats(&self) -> PrefixCacheStats {
        let mut total = PrefixCacheStats::default();
        for g in &self.core.groups {
            if let Some(px) = &g.prefix {
                let s = px.stats();
                total.hits += s.hits;
                total.misses += s.misses;
                total.inserted += s.inserted;
                total.evicted += s.evicted;
                total.demoted += s.demoted;
                total.promoted += s.promoted;
                total.pages_nominal += s.pages_nominal;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::session::SessionConfig;

    fn cfg() -> ServeConfig {
        ServeConfig {
            heads: 2,
            d: 8,
            vocab: 32,
            page_size: 4,
            max_pages: 512,
            max_lanes: 4,
            queue_capacity: 64,
            max_seq: 256,
            model_seed: 7,
            kv_policy: None,
            prefix_cache: None,
            prefill_chunk: 0,
            speculate: None,
            kv_tier: None,
        }
    }

    #[test]
    fn builder_validates_and_mirrors_defaults() {
        let built = ServeConfig::builder().build().expect("defaults are valid");
        let d = ServeConfig::default();
        assert_eq!(format!("{built:?}"), format!("{d:?}"), "builder defaults == Default");
        let err = ServeConfig::builder().max_lanes(0).build().unwrap_err();
        assert!(err.to_string().contains("max_lanes"), "{err}");
        let err = ServeConfig::builder()
            .kv_policy(Some(PagedKvPolicy::H2o { budget: 64, recent: 8 }))
            .prefix_cache(Some(PrefixCacheConfig::default()))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
        // try_new surfaces the same typed error without panicking.
        assert!(ContinuousBatcher::try_new(ServeConfig { max_seq: 1, ..cfg() }).is_err());
    }

    #[test]
    fn reservation_formulas() {
        let c = cfg();
        // 19 prompt + 5 new across 2 heads at page_size 4.
        assert_eq!(pages_reserved(19, 5, &c), 12);
        assert_eq!(pages_reserved_shared(19, 5, 0, &c), 12, "no sharing == worst case");
        // 16 shared tokens release 16/4 = 4 whole pages per head.
        assert_eq!(pages_reserved_shared(19, 5, 16, &c), 4);
        // A mid-page share point releases only the whole pages below it.
        assert_eq!(pages_reserved_shared(19, 5, 18, &c), 4);
        assert!(pages_reserved_shared(19, 5, 19, &c) >= c.heads);
    }

    /// Satellite regression: a sequence that fails after passing
    /// admission checks must leave `group.reserved_pages` at its
    /// pre-admission value — `start_seq` only charges the reservation
    /// after the prefill succeeded, so the failure path has nothing to
    /// give back (and `return_reservation`'s checked subtraction would
    /// catch a double return loudly).
    #[test]
    fn failed_prefill_leaves_reservation_at_pre_admission_value() {
        let c = cfg();
        let mut core = SchedulerCore::new(c);
        let gi = group_index(&mut core.groups, "dense", &c).unwrap();
        // Swap in a session whose page budget cannot hold the prompt,
        // so prefill_lane fails with OutOfPages after admission math
        // (which uses cfg.max_pages) already said yes.
        let tiny = SessionConfig::new(0, c.heads, c.d, c.d).with_paging(c.page_size, 1);
        core.groups[gi].session =
            crate::attention::session::AttentionSession::from_spec("dense", tiny).unwrap();
        let req = ServeRequest::new(vec![1; 40]).max_new(4).engine("dense");
        let before = core.groups[gi].reserved_pages;
        let needed = pages_reserved(40, 4, &c);
        let err = start_seq(
            &core.model,
            &mut core.groups[gi],
            0,
            req,
            Instant::now(),
            &c,
            needed,
            None,
        );
        let (_req, e) = err.expect_err("1-page session cannot prefill 40 tokens");
        assert!(matches!(e, ServeError::Cache(_)), "{e}");
        assert_eq!(
            core.groups[gi].reserved_pages, before,
            "failed prefill must not charge (or double-return) its reservation"
        );
        assert_eq!(core.groups[gi].session.live_lanes(), 0, "failed lane auto-released");
    }

    #[test]
    #[should_panic(expected = "returned its reservation twice")]
    fn double_reservation_return_is_a_loud_accounting_failure() {
        let c = cfg();
        let mut core = SchedulerCore::new(c);
        let gi = group_index(&mut core.groups, "dense", &c).unwrap();
        let req = ServeRequest::new(vec![1, 2, 3, 4]).max_new(2).engine("dense");
        let needed = pages_reserved(4, 2, &c);
        let seq = start_seq(
            &core.model,
            &mut core.groups[gi],
            0,
            req,
            Instant::now(),
            &c,
            needed,
            None,
        )
        .expect("fits comfortably");
        core.groups[gi].return_reservation(&seq);
        assert_eq!(core.groups[gi].reserved_pages, 0);
        core.groups[gi].return_reservation(&seq); // must panic, not wrap
    }

    #[test]
    fn tiered_reservation_discounts_cold_pages() {
        let c = cfg(); // heads 2, page_size 4
        // kv_tier: None is bit-for-bit the legacy accounting.
        assert_eq!(pages_reserved_tiered(19, 5, 0, &c), pages_reserved(19, 5, &c));
        assert_eq!(pages_reserved_tiered(19, 5, 16, &c), pages_reserved_shared(19, 5, 16, &c));
        let t = ServeConfig {
            kv_tier: Some(KvTierCfg { cold_after: 4, policy: TierPolicy::Lru }),
            ..c
        };
        // 32 steady tokens: 28 cold -> 7 cold pages -> ⌊7/2⌋ = 3 pages
        // refunded per head.
        assert_eq!(pages_reserved(16, 16, &t), 16);
        assert_eq!(pages_reserved_tiered(16, 16, 0, &t), 16 - 2 * 3);
        // Shared-prefix pages belong to the prefix cache's nominal
        // budget — excluded from the lane's cold discount.
        assert_eq!(pages_reserved_shared(16, 16, 8, &t), 12);
        assert_eq!(pages_reserved_tiered(16, 16, 8, &t), 12 - 2 * ((7 - 2) / 2));
        // A short sequence never discounts below its hot tail.
        assert_eq!(pages_reserved_tiered(4, 1, 0, &t), pages_reserved(4, 1, &t));
    }

    #[test]
    fn tier_config_validation() {
        let tier = KvTierCfg { cold_after: 4, policy: TierPolicy::Lru };
        assert!(ServeConfig { kv_tier: Some(tier), ..cfg() }.validate().is_ok());
        let err = ServeConfig {
            kv_tier: Some(KvTierCfg { cold_after: 0, policy: TierPolicy::Lru }),
            ..cfg()
        }
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("cold_after"), "{err}");
        let err = ServeConfig {
            kv_tier: Some(tier),
            speculate: Some(SpeculateConfig { draft: parse_spec("dense").unwrap(), gamma: 2 }),
            ..cfg()
        }
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
        let err = ServeConfig {
            kv_tier: Some(KvTierCfg { cold_after: 4, policy: TierPolicy::H2o }),
            ..cfg()
        }
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("requires kv_policy"), "{err}");
        assert!(ServeConfig {
            kv_tier: Some(KvTierCfg { cold_after: 4, policy: TierPolicy::H2o }),
            kv_policy: Some(PagedKvPolicy::H2o { budget: 16, recent: 4 }),
            ..cfg()
        }
        .validate()
        .is_ok());
        // The baseline strip drops tiering with the rest.
        assert!(ServeConfig { kv_tier: Some(tier), ..cfg() }
            .strip_incompatible()
            .kv_tier
            .is_none());
    }

    fn prompt(seed: u64, len: usize, vocab: usize) -> Vec<i32> {
        let mut r = Rng::new(seed);
        (0..len).map(|_| r.below(vocab as u64) as i32).collect()
    }

    fn run_tokens(c: ServeConfig, prompts: &[Vec<i32>], max_new: usize) -> Vec<Vec<i32>> {
        let mut s = ContinuousBatcher::new(c);
        for p in prompts {
            s.submit(ServeRequest::new(p.clone()).max_new(max_new).engine("dense")).unwrap();
        }
        let mut fin = s.run_to_completion();
        fin.sort_by_key(|f| f.id);
        fin.into_iter().map(|f| f.tokens).collect()
    }

    /// The no-demotion identity pin: with `cold_after` longer than any
    /// sequence ever gets, tiering never fires — zero demote/promote
    /// counters and greedy streams bit-for-bit identical to a
    /// tier-free run.
    #[test]
    fn tiering_that_never_triggers_is_bit_for_bit_invisible() {
        let prompts: Vec<Vec<i32>> = (0..3).map(|i| prompt(40 + i, 12, 32)).collect();
        let plain = run_tokens(cfg(), &prompts, 8);
        let tier = ServeConfig {
            kv_tier: Some(KvTierCfg { cold_after: 128, policy: TierPolicy::Lru }),
            ..cfg()
        };
        let mut s = ContinuousBatcher::new(tier);
        for p in &prompts {
            s.submit(ServeRequest::new(p.clone()).max_new(8).engine("dense")).unwrap();
        }
        let mut demoted = 0;
        while s.has_work() {
            let r = s.step();
            demoted += r.pages_demoted + r.pages_promoted;
        }
        assert_eq!(demoted, 0, "cold_after beyond max_seq never demotes");
        assert_eq!(s.tier_max_error_ratio(), 0.0);
        let mut fin = s.take_finished();
        fin.sort_by_key(|f| f.id);
        let tokens: Vec<Vec<i32>> = fin.into_iter().map(|f| f.tokens).collect();
        assert_eq!(tokens, plain, "untriggered tiering must not perturb streams");
    }

    /// Active LRU tiering: demotions land in `StepReport`, every
    /// stream still finishes its full budget, and the observed
    /// round-trip error stays within the quantizer's `scale/2` bound.
    #[test]
    fn tiered_serving_demotes_and_stays_within_error_bound() {
        for tier_policy in [TierPolicy::Lru, TierPolicy::H2o] {
            let c = ServeConfig {
                kv_tier: Some(KvTierCfg { cold_after: 4, policy: tier_policy }),
                kv_policy: (tier_policy == TierPolicy::H2o)
                    .then_some(PagedKvPolicy::H2o { budget: 16, recent: 4 }),
                ..cfg()
            };
            let mut s = ContinuousBatcher::new(c);
            for i in 0..2u64 {
                s.submit(ServeRequest::new(prompt(50 + i, 24, 32)).max_new(12).engine("dense"))
                    .unwrap();
            }
            let mut demoted = 0;
            while s.has_work() {
                demoted += s.step().pages_demoted;
            }
            assert!(demoted > 0, "{tier_policy:?}: long lanes must shed cold pages");
            assert!(
                s.tier_max_error_ratio() <= 1.0 + 1e-3,
                "{tier_policy:?}: dequant error ratio {} above the scale/2 bound",
                s.tier_max_error_ratio()
            );
            let fin = s.take_finished();
            assert_eq!(fin.len(), 2);
            for f in fin {
                assert_eq!(f.tokens.len(), 12, "tiered lanes decode their full budget");
                assert!(matches!(f.state, RequestState::Finished { .. }));
            }
        }
    }

    /// The capacity lever: two requests whose fp32 reservations cannot
    /// coexist under a tight `max_pages` are admitted **together** once
    /// tiering charges them at the compressed steady state.
    #[test]
    fn tiered_admission_raises_concurrency_at_fixed_max_pages() {
        let tight = ServeConfig { max_pages: 26, ..cfg() };
        let prompts: Vec<Vec<i32>> = (0..2).map(|i| prompt(60 + i, 16, 32)).collect();
        // fp32: each reserves 2·⌈32/4⌉ = 16 pages; 32 > 26 serializes.
        let mut plain = ContinuousBatcher::new(tight);
        for p in &prompts {
            plain.submit(ServeRequest::new(p.clone()).max_new(16).engine("dense")).unwrap();
        }
        assert_eq!(plain.step().admitted, 1, "fp32 reservations head-of-line block");
        // Tiered: 16 - ⌊7/2⌋·2 = 10 pages each; 20 <= 26 coexists.
        let tier = ServeConfig {
            kv_tier: Some(KvTierCfg { cold_after: 4, policy: TierPolicy::Lru }),
            ..tight
        };
        let mut s = ContinuousBatcher::new(tier);
        for p in &prompts {
            s.submit(ServeRequest::new(p.clone()).max_new(16).engine("dense")).unwrap();
        }
        assert_eq!(s.step().admitted, 2, "compressed reservations admit the pair");
        assert_eq!(s.live(), 2);
        // Both lanes decode to completion inside the tight budget —
        // the demotion pass keeps the physical pool under control.
        let fin = s.run_to_completion();
        assert_eq!(fin.len(), 2);
        for f in &fin {
            assert_eq!(f.tokens.len(), 16, "both lanes decode to completion inside 26 pages");
            assert!(matches!(f.state, RequestState::Finished { .. }));
        }
    }
}
