//! The [`Scheduler`] trait and its [`ContinuousBatcher`]
//! implementation — request-lifecycle serving over the lane API of
//! [`AttentionSession`].
//!
//! A step is the scheduling quantum. Each [`Scheduler::step`]:
//!
//! 1. **Admits** queued requests into free lanes under the page-budget
//!    policy: a request reserves its worst-case page footprint
//!    (`heads · ⌈(prompt + max_new) / page_size⌉`) at admission, so a
//!    live wave can never run out of pages mid-decode. Admission is
//!    FIFO with head-of-line blocking — a request that doesn't fit
//!    *yet* waits (pages drain as sequences finish); a request that
//!    could *never* fit fails at submission.
//! 2. **Prefills** each admitted request at its own boundary (batch-1,
//!    its own prompt length — no padding to a wave-wide length) and
//!    samples its first token: time-to-first-token does not wait for
//!    any other sequence.
//! 3. **Decodes** one token for every live sequence of every engine
//!    group in one mixed batch per group, then **releases finished
//!    lanes' pages on the same step** — the mid-wave eviction that
//!    makes room for the next admission.
//!
//! Heterogeneous engine families coexist in one scheduler: requests
//! are grouped by canonical engine spec, one `AttentionSession` (and
//! page budget) per group. The queue/group/lifecycle state every
//! scheduler needs lives in [`SchedulerCore`], shared with the
//! [`WaveScheduler`](crate::serve::wave::WaveScheduler) baseline so
//! the two differ only in policy.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::attention::decode::PagedKvPolicy;
use crate::attention::registry::parse_spec;
use crate::attention::session::{AttentionSession, LaneId, SessionConfig};
use crate::attention::HeadTensor;
use crate::coordinator::metrics::ServeMetrics;
use crate::serve::model::{sample, ToyLm};
use crate::serve::request::{
    FinishReason, FinishedRequest, RequestId, RequestState, ServeError, ServeEvent,
    ServeRequest,
};
use crate::util::rng::Rng;

/// Geometry and policy knobs shared by every serve scheduler.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub heads: usize,
    /// Q/K/V dim per head.
    pub d: usize,
    pub vocab: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// KV page budget *per engine group* (each distinct canonical spec
    /// owns its own paged cache).
    pub max_pages: usize,
    /// Maximum concurrently-live sequences across all groups.
    pub max_lanes: usize,
    /// Admission queue bound — `submit` returns
    /// [`ServeError::QueueFull`] beyond it.
    pub queue_capacity: usize,
    /// Context cap: prompt plus generated tokens per sequence.
    pub max_seq: usize,
    /// Seed for the deterministic [`ToyLm`] and per-request samplers.
    pub model_seed: u64,
    /// KV eviction policy for every admitted lane. `None` (default)
    /// keeps worst-case `prompt + max_new` page reservations; `Some`
    /// switches the [`ContinuousBatcher`] to **policy-budget
    /// admission**: each lane reserves only its pruned steady-state
    /// footprint (see [`pages_reserved`]), so more lanes fit the same
    /// page budget. The wave baseline ignores this (it *is* the
    /// worst-case comparison point).
    pub kv_policy: Option<PagedKvPolicy>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            heads: 4,
            d: 32,
            vocab: 64,
            page_size: 16,
            max_pages: 4096,
            max_lanes: 8,
            queue_capacity: 1024,
            max_seq: 4096,
            model_seed: 0x5FA,
            kv_policy: None,
        }
    }
}

impl ServeConfig {
    /// Construction-time sanity: a zero in any of these knobs makes a
    /// scheduler that can never admit work (e.g. `max_lanes == 0`
    /// turns `step()` into a busy-wait that never drains the queue).
    pub(crate) fn assert_valid(&self) {
        assert!(self.heads >= 1 && self.d >= 1 && self.vocab >= 2, "degenerate model geometry");
        assert!(self.page_size >= 1 && self.max_pages >= 1, "degenerate page budget");
        assert!(self.max_lanes >= 1, "max_lanes must be >= 1 (a 0-lane scheduler never admits)");
        assert!(self.queue_capacity >= 1, "queue_capacity must be >= 1");
        assert!(self.max_seq >= 2, "max_seq must fit a prompt token plus a generated token");
    }
}

/// Worst-case page footprint of one sequence: `steps` generated tokens
/// on top of a `prompt_len` prompt, across `heads` per-head sequences.
/// Public so CLI layers pre-check workloads with the *same* formula
/// the admission policy reserves by.
pub fn pages_needed(prompt_len: usize, steps: usize, heads: usize, page_size: usize) -> usize {
    heads * (prompt_len + steps).div_ceil(page_size)
}

/// Pages one request reserves at admission under the configured
/// policy. Worst-case mode (`kv_policy: None`) reserves the full
/// `prompt + steps` footprint. Policy-budget mode reserves the pruned
/// steady state `min(prompt + steps, policy_limit + 1)` tokens (`+1`
/// covers the append that precedes each prune) — the long-prompt
/// prefill spike above that is a *transient*: `prefill_lane` prunes the
/// lane back under budget before the admission pass moves on, so the
/// batcher checks it against the momentarily free pool instead of
/// reserving it for the lane's lifetime.
pub fn pages_reserved(prompt_len: usize, steps: usize, cfg: &ServeConfig) -> usize {
    match &cfg.kv_policy {
        None => pages_needed(prompt_len, steps, cfg.heads, cfg.page_size),
        Some(p) => {
            let peak = (prompt_len + steps).min(p.max_cached_tokens(cfg.page_size) + 1);
            cfg.heads * peak.div_ceil(cfg.page_size)
        }
    }
}

/// What one [`Scheduler::step`] did (the serving loop's observability
/// surface; `bench serve` integrates these into page-occupancy curves).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepReport {
    /// Requests admitted (prefilled) this step.
    pub admitted: usize,
    /// Tokens sampled this step (prefill first-tokens + decode).
    pub decoded_tokens: usize,
    pub finished: usize,
    pub failed: usize,
    /// KV pages returned to the budget this step by finished lanes.
    pub pages_freed: usize,
    /// KV pages returned to the budget this step by policy eviction
    /// (live lanes pruning themselves under their policy budget).
    pub pages_pruned: usize,
    /// KV pages in use across all groups after the step.
    pub pages_in_use: usize,
    /// Live sequences after the step.
    pub live: usize,
}

/// A request-lifecycle scheduler: submit → step until idle → collect.
pub trait Scheduler {
    /// Enqueue a request; typed errors for backpressure and
    /// never-fits requests. `Ok` hands back the request's id.
    fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError>;

    /// Run one scheduling quantum (admissions + one decode step).
    fn step(&mut self) -> StepReport;

    /// Anything queued or mid-flight?
    fn has_work(&self) -> bool;

    /// Current lifecycle state of a request (pruned once its terminal
    /// summary is drained by [`Scheduler::take_finished`]).
    fn state(&self, id: RequestId) -> Option<&RequestState>;

    /// Drain terminal request summaries accumulated so far.
    fn take_finished(&mut self) -> Vec<FinishedRequest>;

    fn metrics(&self) -> &ServeMetrics;
    fn metrics_mut(&mut self) -> &mut ServeMetrics;

    /// KV pages in use across all engine groups.
    fn pages_in_use(&self) -> usize;

    /// Step until idle, then drain the terminal summaries.
    fn run_to_completion(&mut self) -> Vec<FinishedRequest> {
        while self.has_work() {
            self.step();
        }
        self.take_finished()
    }
}

/// Validation shared by every scheduler's `submit`.
pub(crate) fn validate(req: &ServeRequest, cfg: &ServeConfig) -> Result<(), ServeError> {
    if req.prompt.is_empty() {
        return Err(ServeError::EmptyPrompt);
    }
    if req.max_new == 0 {
        return Err(ServeError::NothingToGenerate);
    }
    parse_spec(&req.engine)?;
    if req.prompt.len() + 1 > cfg.max_seq {
        return Err(ServeError::PromptTooLong { len: req.prompt.len(), max_seq: cfg.max_seq });
    }
    let budget_tokens = req.max_new.min(cfg.max_seq - req.prompt.len());
    // A request never fits if its steady-state reservation *or* its
    // prefill-time transient (the whole prompt is paged in before the
    // post-prefill prune) exceeds an empty cache.
    let needed = pages_reserved(req.prompt.len(), budget_tokens, cfg)
        .max(pages_needed(req.prompt.len(), 0, cfg.heads, cfg.page_size));
    if needed > cfg.max_pages {
        return Err(ServeError::PageBudgetExceeded {
            needed_pages: needed,
            budget_pages: cfg.max_pages,
        });
    }
    Ok(())
}

pub(crate) fn emit(req: &ServeRequest, ev: ServeEvent) {
    if let Some(tx) = &req.events {
        let _ = tx.send(ev); // streaming consumer may have gone away
    }
}

pub(crate) fn set_state(
    states: &mut BTreeMap<RequestId, RequestState>,
    req: &ServeRequest,
    id: RequestId,
    state: RequestState,
) {
    emit(req, ServeEvent::State { id, state: state.clone() });
    states.insert(id, state);
}

/// One request waiting for admission.
pub(crate) struct QueuedReq {
    pub id: RequestId,
    pub req: ServeRequest,
    pub submitted: Instant,
}

/// One live sequence occupying a lane.
pub(crate) struct ActiveSeq {
    pub id: RequestId,
    pub req: ServeRequest,
    pub lane: LaneId,
    pub last_token: i32,
    pub generated: Vec<i32>,
    /// Generation cap: `min(max_new, max_seq - prompt_len)`.
    pub budget: usize,
    /// Pages reserved for this sequence at admission.
    pub reserved_pages: usize,
    /// Per-request sampler stream (independent of batch composition).
    pub rng: Rng,
    pub submitted: Instant,
    pub last_token_at: Instant,
    pub ttft_s: f64,
    /// Wave scheduling only: finished but still holding its lane.
    pub done: Option<FinishReason>,
}

/// All sequences sharing one engine spec (and one session / cache).
pub(crate) struct EngineGroup {
    /// Canonical spec string.
    pub spec: String,
    pub session: AttentionSession,
    pub active: Vec<ActiveSeq>,
    /// Worst-case pages promised to live sequences.
    pub reserved_pages: usize,
}

/// Find or create the group for `spec_raw` in `groups`; returns its
/// index (a stable key while no groups are removed — they never are).
pub(crate) fn group_index(
    groups: &mut Vec<EngineGroup>,
    spec_raw: &str,
    cfg: &ServeConfig,
) -> Result<usize, ServeError> {
    let canon = parse_spec(spec_raw)?.canonical();
    if let Some(i) = groups.iter().position(|g| g.spec == canon) {
        return Ok(i);
    }
    let scfg =
        SessionConfig::new(0, cfg.heads, cfg.d, cfg.d).with_paging(cfg.page_size, cfg.max_pages);
    let session = AttentionSession::from_spec(&canon, scfg)?;
    groups.push(EngineGroup { spec: canon, session, active: Vec::new(), reserved_pages: 0 });
    Ok(groups.len() - 1)
}

/// Prefill one admitted request into `group` at its own boundary and
/// sample its first token. On failure the lane is gone (prefill_lane
/// auto-releases) and the request is handed back with the error.
pub(crate) fn start_seq(
    model: &ToyLm,
    group: &mut EngineGroup,
    id: RequestId,
    req: ServeRequest,
    submitted: Instant,
    cfg: &ServeConfig,
    reserved_pages: usize,
) -> Result<ActiveSeq, (ServeRequest, ServeError)> {
    let plen = req.prompt.len();
    let budget = req.max_new.min(cfg.max_seq - plen);
    let (q, k, v) = model.qkv_prompt(&req.prompt, 0);
    // Policy-budget serving admits every lane with its eviction
    // policy; prefill_lane prunes a long prompt back under the budget
    // before this call returns, so the reservation accounting below
    // only ever has to cover the pruned steady state.
    let lane = match &cfg.kv_policy {
        Some(p) => group.session.admit_lane_with_policy(p),
        None => group.session.admit_lane(),
    };
    let out = match group.session.prefill_lane(lane, &q, &k, &v, true) {
        Ok(o) => o,
        Err(e) => return Err((req, e.into())),
    };
    let logits = model.logits_at(&out, 0, plen - 1);
    let mut rng = Rng::new(cfg.model_seed ^ req.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let tok = sample(&logits, req.sampling, &mut rng);
    let now = Instant::now();
    group.reserved_pages += reserved_pages;
    Ok(ActiveSeq {
        id,
        req,
        lane,
        last_token: tok,
        generated: vec![tok],
        budget,
        reserved_pages,
        rng,
        submitted,
        last_token_at: now,
        ttft_s: now.duration_since(submitted).as_secs_f64(),
        done: None,
    })
}

/// Has this sequence just finished, and why?
pub(crate) fn finish_reason(seq: &ActiveSeq) -> Option<FinishReason> {
    let last = *seq.generated.last().expect("active sequence has at least one token");
    if seq.req.stop_tokens.contains(&last) {
        return Some(FinishReason::StopToken);
    }
    if seq.generated.len() >= seq.budget {
        return Some(if seq.budget < seq.req.max_new {
            FinishReason::ContextFull
        } else {
            FinishReason::MaxTokens
        });
    }
    None
}

/// Terminal summary for a sequence (total latency measured now — for
/// wave scheduling that is wave-end, the moment the old API delivered).
pub(crate) fn finished_record(
    seq: &ActiveSeq,
    spec: &str,
    state: RequestState,
) -> FinishedRequest {
    FinishedRequest {
        id: seq.id,
        engine: spec.to_string(),
        prompt_len: seq.req.prompt.len(),
        tokens: seq.generated.clone(),
        state,
        ttft_s: seq.ttft_s,
        total_s: seq.submitted.elapsed().as_secs_f64(),
    }
}

/// State every serve scheduler carries: the bounded admission queue,
/// engine groups, the lifecycle map, terminal records, and metrics.
/// `ContinuousBatcher` and `WaveScheduler` embed this and differ only
/// in their `step()` policy.
pub(crate) struct SchedulerCore {
    pub cfg: ServeConfig,
    pub model: ToyLm,
    pub queue: VecDeque<QueuedReq>,
    pub groups: Vec<EngineGroup>,
    pub states: BTreeMap<RequestId, RequestState>,
    pub finished: Vec<FinishedRequest>,
    pub metrics: ServeMetrics,
    pub next_id: RequestId,
}

impl SchedulerCore {
    /// Panics on a degenerate config (see `ServeConfig::assert_valid`);
    /// CLI layers should range-check user input first.
    pub fn new(cfg: ServeConfig) -> SchedulerCore {
        cfg.assert_valid();
        SchedulerCore {
            model: ToyLm::new(cfg.heads, cfg.d, cfg.vocab, cfg.model_seed),
            cfg,
            queue: VecDeque::new(),
            groups: Vec::new(),
            states: BTreeMap::new(),
            finished: Vec::new(),
            metrics: ServeMetrics::default(),
            next_id: 0,
        }
    }

    /// Shared `Scheduler::submit` body: validate, enforce the queue
    /// bound, assign an id, record `Queued`, enqueue.
    pub fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError> {
        validate(&req, &self.cfg)?;
        if self.queue.len() >= self.cfg.queue_capacity {
            return Err(ServeError::QueueFull { capacity: self.cfg.queue_capacity });
        }
        let id = self.next_id;
        self.next_id += 1;
        set_state(&mut self.states, &req, id, RequestState::Queued);
        self.queue.push_back(QueuedReq { id, req, submitted: Instant::now() });
        Ok(id)
    }

    pub fn state(&self, id: RequestId) -> Option<&RequestState> {
        self.states.get(&id)
    }

    /// Drain terminal summaries and prune their lifecycle entries, so a
    /// long-running scheduler's state map stays bounded by queued +
    /// live requests instead of growing with every request ever served.
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        let out = std::mem::take(&mut self.finished);
        for f in &out {
            self.states.remove(&f.id);
        }
        out
    }

    pub fn pages_in_use(&self) -> usize {
        self.groups.iter().map(|g| g.session.pages_in_use()).sum()
    }

    /// Terminal failure: `Failed` state, empty-token summary, metric.
    pub fn fail_request(&mut self, id: RequestId, req: &ServeRequest, e: ServeError) {
        set_state(&mut self.states, req, id, RequestState::Failed { error: e.clone() });
        self.finished.push(FinishedRequest {
            id,
            engine: req.engine.clone(),
            prompt_len: req.prompt.len(),
            tokens: Vec::new(),
            state: RequestState::Failed { error: e },
            ttft_s: 0.0,
            total_s: 0.0,
        });
        self.metrics.record_failed();
    }
}

/// Continuous batching: sequences join a live decode wave at their own
/// prefill boundary and leave (freeing pages) the step they finish.
pub struct ContinuousBatcher {
    core: SchedulerCore,
}

impl ContinuousBatcher {
    /// Panics on a degenerate config (see `ServeConfig::assert_valid`);
    /// CLI layers should range-check user input first.
    pub fn new(cfg: ServeConfig) -> ContinuousBatcher {
        ContinuousBatcher { core: SchedulerCore::new(cfg) }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.core.cfg
    }

    /// Live sequences across all groups.
    pub fn live(&self) -> usize {
        self.core.groups.iter().map(|g| g.active.len()).sum()
    }

    /// Requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.core.queue.len()
    }

    /// Admission pass: fill free lanes from the queue under the page
    /// budget. FIFO with head-of-line blocking on a not-yet-fitting
    /// request.
    fn admit(&mut self, report: &mut StepReport) {
        while let Some(front) = self.core.queue.front() {
            if self.live() >= self.core.cfg.max_lanes {
                break;
            }
            let gi = match group_index(&mut self.core.groups, &front.req.engine, &self.core.cfg)
            {
                Ok(gi) => gi,
                Err(e) => {
                    // Spec parsed at submit but the session rejected it
                    // (e.g. feature budget k > head dim d).
                    let qr = self.core.queue.pop_front().expect("front exists");
                    self.core.fail_request(qr.id, &qr.req, e);
                    report.failed += 1;
                    continue;
                }
            };
            let plen = front.req.prompt.len();
            let budget_tokens = front.req.max_new.min(self.core.cfg.max_seq - plen);
            let needed = pages_reserved(plen, budget_tokens, &self.core.cfg);
            if self.core.groups[gi].reserved_pages + needed > self.core.cfg.max_pages {
                break; // wait for pages to drain
            }
            if self.core.cfg.kv_policy.is_some() {
                // Transient check: the whole prompt is paged in during
                // prefill before the post-prefill prune shrinks it to
                // the reservation. Live lanes never exceed their own
                // reservations, so the instantaneously free pool is a
                // safe bound; the transient resolves inside this same
                // admission pass.
                let transient =
                    pages_needed(plen, 0, self.core.cfg.heads, self.core.cfg.page_size);
                if transient > self.core.groups[gi].session.pages_free() {
                    break; // wait for pages to drain
                }
            }
            let QueuedReq { id, req, submitted } =
                self.core.queue.pop_front().expect("front exists");
            set_state(&mut self.core.states, &req, id, RequestState::Prefilling);
            let seq = match start_seq(
                &self.core.model,
                &mut self.core.groups[gi],
                id,
                req,
                submitted,
                &self.core.cfg,
                needed,
            ) {
                Ok(seq) => seq,
                Err((req, e)) => {
                    self.core.fail_request(id, &req, e);
                    report.failed += 1;
                    continue;
                }
            };
            report.admitted += 1;
            report.decoded_tokens += 1; // the TTFT token
            set_state(&mut self.core.states, &seq.req, id, RequestState::Decoding);
            emit(&seq.req, ServeEvent::Token { id, index: 0, token: seq.last_token });
            if let Some(reason) = finish_reason(&seq) {
                self.retire(gi, seq, reason, report);
            } else {
                self.core.groups[gi].active.push(seq);
            }
        }
    }

    /// Release a finished sequence's lane and record its summary — on
    /// the same step it finished (the scheduler-invariant the tests
    /// pin).
    fn retire(&mut self, gi: usize, seq: ActiveSeq, reason: FinishReason, report: &mut StepReport) {
        let group = &mut self.core.groups[gi];
        let freed = group.session.release_lane(seq.lane).unwrap_or(0);
        group.reserved_pages -= seq.reserved_pages;
        report.pages_freed += freed;
        report.finished += 1;
        let state = RequestState::Finished { reason };
        set_state(&mut self.core.states, &seq.req, seq.id, state.clone());
        self.core.metrics.record_finished(
            seq.ttft_s,
            seq.submitted.elapsed().as_secs_f64(),
            seq.generated.len(),
        );
        self.core.finished.push(finished_record(&seq, &self.core.groups[gi].spec, state));
    }

    /// One mixed decode step per engine group over all its live lanes.
    /// Index iteration is load-bearing: the body calls `&mut self`
    /// methods (retire / fail_request) that an iterator borrow would
    /// forbid.
    fn decode(&mut self, report: &mut StepReport) {
        for gi in 0..self.core.groups.len() {
            let n = self.core.groups[gi].active.len();
            if n == 0 {
                continue;
            }
            let heads = self.core.cfg.heads;
            let d = self.core.cfg.d;
            let mut q = HeadTensor::zeros(n, heads, 1, d);
            let mut k = HeadTensor::zeros(n, heads, 1, d);
            let mut v = HeadTensor::zeros(n, heads, 1, d);
            let mut lanes: Vec<LaneId> = Vec::with_capacity(n);
            for (bi, seq) in self.core.groups[gi].active.iter().enumerate() {
                let pos = self.core.groups[gi].session.lane_len(seq.lane);
                self.core.model.fill_decode_row(&mut q, &mut k, &mut v, bi, seq.last_token, pos);
                lanes.push(seq.lane);
            }
            let out = match self.core.groups[gi].session.decode_step_lanes(&lanes, &q, &k, &v) {
                Ok(o) => o,
                Err(e) => {
                    // Unreachable under reservation accounting; fail
                    // the whole group defensively rather than panic.
                    let seqs = std::mem::take(&mut self.core.groups[gi].active);
                    for seq in seqs {
                        let _ = self.core.groups[gi].session.release_lane(seq.lane);
                        self.core.groups[gi].reserved_pages -= seq.reserved_pages;
                        self.core.fail_request(seq.id, &seq.req, ServeError::from(e));
                        report.failed += 1;
                    }
                    continue;
                }
            };
            let now = Instant::now();
            let mut done: Vec<(usize, FinishReason)> = Vec::new();
            for (bi, seq) in self.core.groups[gi].active.iter_mut().enumerate() {
                let logits = self.core.model.logits_at(&out, bi, 0);
                let tok = sample(&logits, seq.req.sampling, &mut seq.rng);
                seq.last_token = tok;
                seq.generated.push(tok);
                emit(
                    &seq.req,
                    ServeEvent::Token { id: seq.id, index: seq.generated.len() - 1, token: tok },
                );
                self.core
                    .metrics
                    .record_token_latency(now.duration_since(seq.last_token_at).as_secs_f64());
                seq.last_token_at = now;
                report.decoded_tokens += 1;
                if let Some(reason) = finish_reason(seq) {
                    done.push((bi, reason));
                }
            }
            // Evict finished lanes immediately (descending index keeps
            // the remaining swap_remove targets stable).
            for &(bi, reason) in done.iter().rev() {
                let seq = self.core.groups[gi].active.swap_remove(bi);
                self.retire(gi, seq, reason, report);
            }
        }
    }
}

impl Scheduler for ContinuousBatcher {
    fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError> {
        self.core.submit(req)
    }

    fn step(&mut self) -> StepReport {
        let mut report = StepReport::default();
        self.admit(&mut report);
        self.decode(&mut report);
        report.pages_pruned =
            self.core.groups.iter_mut().map(|g| g.session.take_policy_freed()).sum();
        report.pages_in_use = self.core.pages_in_use();
        report.live = self.live();
        report
    }

    fn has_work(&self) -> bool {
        !self.core.queue.is_empty() || self.live() > 0
    }

    fn state(&self, id: RequestId) -> Option<&RequestState> {
        self.core.state(id)
    }

    fn take_finished(&mut self) -> Vec<FinishedRequest> {
        self.core.take_finished()
    }

    fn metrics(&self) -> &ServeMetrics {
        &self.core.metrics
    }

    fn metrics_mut(&mut self) -> &mut ServeMetrics {
        &mut self.core.metrics
    }

    fn pages_in_use(&self) -> usize {
        self.core.pages_in_use()
    }
}
