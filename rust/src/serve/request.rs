//! Request-lifecycle types for the serve API: the [`ServeRequest`]
//! builder, the typed [`RequestState`] state machine, streaming
//! [`ServeEvent`]s, and the [`ServeError`] taxonomy every layer of the
//! serving stack (scheduler admission, router backpressure, page
//! budget) reports through.
//!
//! ```text
//! Queued ──► Prefilling { consumed, total } ──► Decoding ──► Finished { reason }
//!    │            │ (consumed advances            │
//!    │            │  chunk-by-chunk)              │
//!    └────────────┴───────────────────────────────┴─────► Failed { error }
//! ```

use std::sync::mpsc::Sender;

use crate::attention::registry::SpecError;
use crate::kv_cache::paged::PageError;

/// Scheduler-assigned request handle.
pub type RequestId = u64;

/// Every way a serve request can fail, from submission to completion.
/// Backpressure (`QueueFull`) is part of the API from day one: callers
/// see a typed error, not an unboundedly growing queue.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The admission queue is at capacity — retry later.
    QueueFull { capacity: usize },
    /// The request could never fit the cache's page budget, even with
    /// the whole cache to itself.
    PageBudgetExceeded { needed_pages: usize, budget_pages: usize },
    /// Prompt plus one generated token would exceed the context cap.
    PromptTooLong { len: usize, max_seq: usize },
    EmptyPrompt,
    /// `max_new == 0` — nothing to generate.
    NothingToGenerate,
    /// The engine spec string did not parse or build.
    BadSpec(String),
    /// The paged KV cache failed mid-flight.
    Cache(PageError),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { capacity } => {
                write!(f, "admission queue full (capacity {capacity})")
            }
            ServeError::PageBudgetExceeded { needed_pages, budget_pages } => write!(
                f,
                "request needs {needed_pages} KV pages but the budget is {budget_pages}"
            ),
            ServeError::PromptTooLong { len, max_seq } => {
                write!(f, "prompt of {len} tokens exceeds max_seq {max_seq}")
            }
            ServeError::EmptyPrompt => write!(f, "empty prompt"),
            ServeError::NothingToGenerate => write!(f, "max_new is 0"),
            ServeError::BadSpec(msg) => write!(f, "bad engine spec: {msg}"),
            ServeError::Cache(e) => write!(f, "KV cache error: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<SpecError> for ServeError {
    fn from(e: SpecError) -> ServeError {
        ServeError::BadSpec(e.0)
    }
}

impl From<PageError> for ServeError {
    fn from(e: PageError) -> ServeError {
        ServeError::Cache(e)
    }
}

/// Next-token selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeSampling {
    /// Deterministic argmax (first max wins) — the policy the
    /// solo-vs-batched bit-for-bit equivalence tests pin.
    Greedy,
    /// Softmax sampling with temperature, seeded per request so the
    /// draw sequence is independent of batch composition.
    Temperature(f32),
}

/// Per-request service-level objective class — the router's admission
/// priority and the goodput accounting unit (`bench serve --replicas`).
///
/// Spec grammar (shared [`crate::util::spec`] tokenizer):
/// `interactive[:ttft_ms=250,tpot_ms=50]` | `batch`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SloClass {
    /// Latency-sensitive traffic with deadlines: time-to-first-token
    /// and mean per-output-token latency, both in seconds. Tokens from
    /// a request that misses either deadline don't count as goodput.
    Interactive { ttft_s: f64, tpot_s: f64 },
    /// Throughput traffic: no deadline, every token is goodput, and
    /// the scheduler may preempt its lanes under interactive pressure.
    Batch,
}

impl Default for SloClass {
    fn default() -> SloClass {
        SloClass::Batch
    }
}

impl SloClass {
    pub fn is_interactive(&self) -> bool {
        matches!(self, SloClass::Interactive { .. })
    }

    /// Canonical spec string (`SloClass::parse` round-trips it).
    pub fn label(&self) -> String {
        match *self {
            SloClass::Batch => "batch".into(),
            SloClass::Interactive { ttft_s, tpot_s } => format!(
                "interactive:ttft_ms={},tpot_ms={}",
                ttft_s * 1e3,
                tpot_s * 1e3
            ),
        }
    }

    /// Parse `interactive[:ttft_ms=250,tpot_ms=50]` | `batch` through
    /// the shared spec grammar (defaults: 250 ms TTFT, 50 ms TPOT).
    pub fn parse(spec: &str) -> Result<SloClass, String> {
        let raw = crate::util::spec::tokenize(spec)?;
        let family = raw.family;
        if family == "batch" {
            if let Some(&(k, v)) = raw.pairs.first() {
                return Err(format!("batch takes no parameters, got {:?}", format!("{k}={v}")));
            }
            return Ok(SloClass::Batch);
        }
        if family != "interactive" {
            return Err(format!(
                "unknown SLO class {family:?} — known: interactive, batch"
            ));
        }
        let mut ttft_ms = 250.0f64;
        let mut tpot_ms = 50.0f64;
        for &(k, v) in &raw.pairs {
            let ms: f64 = match v.parse() {
                Ok(x) if x > 0.0 && f64::is_finite(x) => x,
                _ => {
                    return Err(format!(
                        "{family}: key {k:?} expects a positive number of ms, got {v:?}"
                    ))
                }
            };
            match k {
                "ttft_ms" => ttft_ms = ms,
                "tpot_ms" => tpot_ms = ms,
                other => return Err(format!("{family}: unknown key {other:?}")),
            }
        }
        Ok(SloClass::Interactive { ttft_s: ttft_ms / 1e3, tpot_s: tpot_ms / 1e3 })
    }

    /// Did a request with this SLO meet its deadlines? `ttft_s` is its
    /// observed time-to-first-token, `tpot_s` its mean per-output-token
    /// latency after the first. Batch always passes.
    pub fn within(&self, ttft_s: f64, tpot_s: f64) -> bool {
        match *self {
            SloClass::Batch => true,
            SloClass::Interactive { ttft_s: ttft_max, tpot_s: tpot_max } => {
                ttft_s <= ttft_max && tpot_s <= tpot_max
            }
        }
    }
}

/// Why a request finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced its `max_new` tokens.
    MaxTokens,
    /// Emitted one of the request's stop tokens (included in the
    /// output).
    StopToken,
    /// Hit the scheduler's context cap before `max_new`.
    ContextFull,
}

/// The request lifecycle. States only move forward; `Finished` and
/// `Failed` are terminal.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestState {
    Queued,
    /// Prompt ingestion in flight. Under chunked prefill
    /// (`ServeConfig::prefill_chunk > 0`) `consumed` advances by one
    /// chunk per scheduler step, with a `State` event per chunk; the
    /// legacy monolithic path jumps straight from `consumed: 0` to
    /// `Decoding` in one step. `consumed` counts prompt tokens whose
    /// KV is cached, including any radix-cache shared prefix.
    Prefilling { consumed: usize, total: usize },
    Decoding,
    Finished { reason: FinishReason },
    Failed { error: ServeError },
}

impl RequestState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, RequestState::Finished { .. } | RequestState::Failed { .. })
    }
}

/// Streaming per-token events, delivered on the channel the request
/// was built with (instead of one blocking end-of-wave response).
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// The request moved to a new lifecycle state.
    State { id: RequestId, state: RequestState },
    /// One generated token (`index` counts from 0; index 0 is the
    /// time-to-first-token sample produced by prefill).
    Token { id: RequestId, index: usize, token: i32 },
    /// Admission-time re-routing: the router withdrew this still-queued
    /// request from a page-pressured replica and resubmitted it to the
    /// current cost-model winner. Fires before any prefill work, so the
    /// token stream is unaffected (`id` is the router-global id).
    Migrated { id: RequestId, from: usize, to: usize },
}

/// A generation request: build with [`ServeRequest::new`], refine with
/// the chained setters, hand to a `serve::Scheduler`.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Engine registry spec string (`"sfa:k=8,bq=64,bk=64"`, `"dense"`,
    /// …) — heterogeneous engine families coexist in one serving
    /// process, one session per distinct canonical spec.
    pub engine: String,
    pub sampling: ServeSampling,
    /// Sampler stream seed — a property of the *request*, not of the
    /// scheduler, so a temperature-sampled request draws the same
    /// tokens whether it runs solo or inside a busy batch.
    pub seed: u64,
    /// Generation stops when any of these tokens is emitted.
    pub stop_tokens: Vec<i32>,
    /// Service-level objective class (default [`SloClass::Batch`]):
    /// interactive requests get admission priority and may preempt
    /// batch lanes; their tokens only count as goodput within deadline.
    pub slo: SloClass,
    /// Streaming event sink; `None` means fire-and-collect (results via
    /// `Scheduler::take_finished`).
    pub events: Option<Sender<ServeEvent>>,
}

impl ServeRequest {
    pub fn new(prompt: Vec<i32>) -> ServeRequest {
        ServeRequest {
            prompt,
            max_new: 16,
            engine: "sfa:k=8".into(),
            sampling: ServeSampling::Greedy,
            seed: 0,
            stop_tokens: Vec::new(),
            slo: SloClass::Batch,
            events: None,
        }
    }

    pub fn seed(mut self, seed: u64) -> ServeRequest {
        self.seed = seed;
        self
    }

    pub fn max_new(mut self, n: usize) -> ServeRequest {
        self.max_new = n;
        self
    }

    pub fn engine(mut self, spec: &str) -> ServeRequest {
        self.engine = spec.to_string();
        self
    }

    pub fn sampling(mut self, s: ServeSampling) -> ServeRequest {
        self.sampling = s;
        self
    }

    pub fn stop_tokens(mut self, toks: Vec<i32>) -> ServeRequest {
        self.stop_tokens = toks;
        self
    }

    pub fn slo(mut self, slo: SloClass) -> ServeRequest {
        self.slo = slo;
        self
    }

    pub fn events(mut self, tx: Sender<ServeEvent>) -> ServeRequest {
        self.events = Some(tx);
        self
    }
}

/// Terminal summary of one request (the non-streaming result surface).
#[derive(Debug, Clone)]
pub struct FinishedRequest {
    pub id: RequestId,
    /// Canonical engine spec the request ran under.
    pub engine: String,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// `Finished { .. }` or `Failed { .. }`.
    pub state: RequestState,
    /// Time to first token (queue wait + prefill + first sample), s.
    pub ttft_s: f64,
    /// Submission-to-terminal latency, s.
    pub total_s: f64,
    /// Prompt tokens served from the radix prefix cache (0 on a miss
    /// or when `ServeConfig::prefix_cache` is off) — the per-request
    /// hit observability `bench serve --prefix-cache` aggregates.
    pub prefix_shared: usize,
    /// SLO class the request ran under — goodput accounting pairs it
    /// with `ttft_s`/`total_s`/`tokens` after the fact.
    pub slo: SloClass,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_setters() {
        let r = ServeRequest::new(vec![1, 2, 3]);
        assert_eq!(r.max_new, 16);
        assert_eq!(r.sampling, ServeSampling::Greedy);
        assert!(r.stop_tokens.is_empty() && r.events.is_none());
        let r = r.max_new(4).engine("dense").stop_tokens(vec![0]).sampling(
            ServeSampling::Temperature(0.7),
        );
        assert_eq!(r.max_new, 4);
        assert_eq!(r.engine, "dense");
        assert_eq!(r.stop_tokens, vec![0]);
        assert_eq!(r.sampling, ServeSampling::Temperature(0.7));
    }

    #[test]
    fn terminal_states() {
        assert!(!RequestState::Queued.is_terminal());
        assert!(!RequestState::Prefilling { consumed: 0, total: 4 }.is_terminal());
        assert!(!RequestState::Decoding.is_terminal());
        assert!(RequestState::Finished { reason: FinishReason::MaxTokens }.is_terminal());
        assert!(RequestState::Failed { error: ServeError::EmptyPrompt }.is_terminal());
    }

    #[test]
    fn slo_class_parse_label_roundtrip_and_deadlines() {
        assert_eq!(SloClass::parse("batch").unwrap(), SloClass::Batch);
        assert_eq!(SloClass::default(), SloClass::Batch);
        let slo = SloClass::parse("interactive").unwrap();
        assert_eq!(slo, SloClass::Interactive { ttft_s: 0.25, tpot_s: 0.05 });
        let slo = SloClass::parse("interactive:ttft_ms=100,tpot_ms=20").unwrap();
        assert_eq!(slo, SloClass::Interactive { ttft_s: 0.1, tpot_s: 0.02 });
        assert!(slo.is_interactive());
        assert_eq!(SloClass::parse(&slo.label()).unwrap(), slo, "label round-trips");
        assert_eq!(SloClass::parse(&SloClass::Batch.label()).unwrap(), SloClass::Batch);

        // Deadlines: batch always passes; interactive needs both.
        assert!(SloClass::Batch.within(1e9, 1e9));
        assert!(slo.within(0.1, 0.02));
        assert!(!slo.within(0.11, 0.01), "TTFT over deadline");
        assert!(!slo.within(0.01, 0.03), "TPOT over deadline");

        // Shared-grammar errors.
        for (s, needle) in [
            ("vip", "unknown SLO class"),
            ("interactive:ttft", "key=value"),
            ("interactive:ttft_ms=0", "positive number"),
            ("interactive:ttft_ms=nan", "positive number"),
            ("interactive:window=4", "unknown key"),
            ("interactive:ttft_ms=1,ttft_ms=2", "duplicate"),
            ("batch:ttft_ms=5", "no parameters"),
            ("", "empty spec"),
        ] {
            let e = SloClass::parse(s).unwrap_err();
            assert!(e.contains(needle), "{s:?} -> {e}");
        }
    }

    #[test]
    fn errors_display_and_convert() {
        let e: ServeError = PageError::OutOfPages.into();
        assert_eq!(e, ServeError::Cache(PageError::OutOfPages));
        assert!(e.to_string().contains("out of pages"));
        let e: ServeError =
            crate::attention::registry::parse_spec("warp").unwrap_err().into();
        assert!(matches!(e, ServeError::BadSpec(_)), "{e}");
        let q = ServeError::QueueFull { capacity: 8 };
        assert!(q.to_string().contains("capacity 8"));
    }
}
