//! Generation engine: executes one batch *wave* — batched prefill via
//! the AOT `prefill_b{B}` entry, then a decode loop over `decode_b{B}`
//! until every slot has produced its tokens.
//!
//! The KV caches (dense arrays for the dense variant, top-k value +
//! index tensors for SFA — the paper's App-J memory layout) are opaque
//! literals threaded from prefill's outputs through each decode step's
//! inputs: the decode tuple is IO-symmetric by construction (see
//! python/tests/test_aot.py::test_decode_io_symmetry).

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::request::{GenRequest, GenResponse};
use crate::runtime::{HostTensor, Runtime};
use crate::util::rng::Rng;

/// Sampling policy for next-token selection.
#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    Greedy,
    /// Softmax sampling with temperature.
    Temperature(f32),
}

pub struct Engine<'rt> {
    pub runtime: &'rt Runtime,
    pub variant: String,
    pub batch_size: usize,
    pub sampling: Sampling,
    params: Vec<xla::Literal>,
    prefill_seq: usize,
    max_seq: usize,
    vocab: usize,
    rng: Rng,
    /// Cumulative decode steps across waves (metrics).
    pub decode_steps: u64,
}

impl<'rt> Engine<'rt> {
    pub fn new(
        runtime: &'rt Runtime,
        variant: &str,
        batch_size: usize,
        sampling: Sampling,
        seed: u64,
    ) -> Result<Engine<'rt>> {
        let v = runtime.manifest.variant(variant)?;
        let pre = v.entry(&format!("prefill_b{batch_size}")).context(
            "variant was not compiled with this serve batch size",
        )?;
        let params = runtime.load_weights(variant)?;
        Ok(Engine {
            runtime,
            variant: variant.to_string(),
            batch_size,
            sampling,
            params,
            prefill_seq: pre.seq,
            max_seq: runtime.manifest.max_seq,
            vocab: v.cfg_usize("vocab")?,
            rng: Rng::new(seed),
            decode_steps: 0,
        })
    }

    /// Replace the model weights (e.g. with a trained checkpoint).
    pub fn set_params(&mut self, params: Vec<xla::Literal>) -> Result<()> {
        if params.len() != self.params.len() {
            bail!("param count mismatch");
        }
        self.params = params;
        Ok(())
    }

    fn sample(&mut self, logits_row: &[f32]) -> i32 {
        match self.sampling {
            Sampling::Greedy => argmax(logits_row) as i32,
            Sampling::Temperature(t) => {
                let inv = 1.0 / t.max(1e-4);
                let m = logits_row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let weights: Vec<f64> =
                    logits_row.iter().map(|&x| (((x - m) * inv) as f64).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut u = self.rng.next_f64() * total;
                for (i, w) in weights.iter().enumerate() {
                    u -= w;
                    if u <= 0.0 {
                        return i as i32;
                    }
                }
                (weights.len() - 1) as i32
            }
        }
    }

    /// Execute one wave over up to `batch_size` requests. Padding slots
    /// (when the batcher fires a partial batch) replay slot 0's prompt
    /// and are discarded.
    ///
    /// **Deprecated**: wave execution is structurally synchronous — a
    /// finished slot keeps decoding (and holds its cache tensors) until
    /// the slowest request completes, and responses block until wave
    /// end. `serve::ContinuousBatcher` schedules mixed prefill/decode
    /// steps with per-token streaming and mid-wave page eviction.
    #[deprecated(
        note = "wave-synchronous path; use serve::ContinuousBatcher \
                (request-lifecycle API) for new code"
    )]
    pub fn run_wave(&mut self, requests: &[GenRequest], worker: usize) -> Result<Vec<GenResponse>> {
        if requests.is_empty() || requests.len() > self.batch_size {
            bail!("wave must have 1..={} requests", self.batch_size);
        }
        let b = self.batch_size;
        let wave_start = Instant::now();

        // --- Prefill -----------------------------------------------------
        let mut tokens = vec![0i32; b * self.prefill_seq];
        let mut lengths = vec![1i32; b];
        for (slot, req) in requests.iter().enumerate() {
            let plen = req.prompt.len().min(self.prefill_seq);
            if plen == 0 {
                bail!("empty prompt (request {})", req.id);
            }
            tokens[slot * self.prefill_seq..slot * self.prefill_seq + plen]
                .copy_from_slice(&req.prompt[req.prompt.len() - plen..]);
            lengths[slot] = plen as i32;
        }
        // Idle slots replay request 0 (results discarded).
        for slot in requests.len()..b {
            let plen = lengths[0] as usize;
            let src: Vec<i32> =
                tokens[0..plen].to_vec();
            tokens[slot * self.prefill_seq..slot * self.prefill_seq + plen]
                .copy_from_slice(&src);
            lengths[slot] = lengths[0];
        }

        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            args.push(crate::train::trainer::clone_literal(p)?);
        }
        let n_params = args.len();
        args.push(HostTensor::I32(tokens, vec![b, self.prefill_seq]).to_literal()?);
        args.push(HostTensor::I32(lengths.clone(), vec![b]).to_literal()?);
        let entry = format!("prefill_b{b}");
        let mut outs = self.runtime.run(&self.variant, &entry, &args)?;
        let logits_last = HostTensor::from_literal(&outs.remove(0))?;
        let mut caches = outs; // per-layer cache tensors, opaque

        // First sampled token per slot.
        let lf = logits_last.as_f32()?;
        let mut current: Vec<i32> = (0..b)
            .map(|slot| self.sample(&lf[slot * self.vocab..(slot + 1) * self.vocab]))
            .collect();
        let ttft = wave_start.elapsed().as_secs_f64();

        let mut generated: Vec<Vec<i32>> = (0..b).map(|s| vec![current[s]]).collect();
        let mut pos: Vec<i32> = lengths.clone(); // slot's next write position
        let max_new = requests.iter().map(|r| r.max_new).max().unwrap_or(1);

        // --- Decode loop ---------------------------------------------------
        let decode_entry = format!("decode_b{b}");
        for _step in 1..max_new {
            // Stop early if every live slot is done.
            let live = requests
                .iter()
                .enumerate()
                .any(|(s, r)| generated[s].len() < r.max_new && (pos[s] as usize) < self.max_seq);
            if !live {
                break;
            }
            args.truncate(n_params);
            args.extend(caches.drain(..));
            args.push(HostTensor::I32(current.clone(), vec![b]).to_literal()?);
            let clamped: Vec<i32> = pos
                .iter()
                .map(|&p| p.min(self.max_seq as i32 - 1))
                .collect();
            args.push(HostTensor::I32(clamped, vec![b]).to_literal()?);
            let mut outs = self.runtime.run(&self.variant, &decode_entry, &args)?;
            let logits = HostTensor::from_literal(&outs.remove(0))?;
            caches = outs;
            self.decode_steps += 1;
            let lf = logits.as_f32()?;
            for slot in 0..b {
                let tok = self.sample(&lf[slot * self.vocab..(slot + 1) * self.vocab]);
                current[slot] = tok;
                pos[slot] += 1;
                if slot < requests.len()
                    && generated[slot].len() < requests[slot].max_new
                    && (pos[slot] as usize) < self.max_seq
                {
                    generated[slot].push(tok);
                }
            }
        }

        let total = wave_start.elapsed().as_secs_f64();
        Ok(requests
            .iter()
            .enumerate()
            .map(|(slot, req)| GenResponse {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: generated[slot].clone(),
                ttft_s: ttft,
                total_s: total,
                worker,
            })
            .collect())
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
        assert_eq!(argmax(&[-3.0]), 0);
    }

    // Engine integration tests (against real artifacts) live in
    // rust/tests/integration.rs.
}
