//! Batch formation policy: a size-or-deadline admission queue.
//!
//! Requests accumulate until either `max_batch` are waiting (fire a
//! full batch) or the oldest request has waited `max_wait` (fire a
//! partial batch padded with idle slots). Wave execution is handled by
//! the engine.
//!
//! **Deprecated path**: this queue feeds the wave-synchronous
//! coordinator. The primary serving API is `crate::serve` (a
//! request-lifecycle scheduler with true continuous batching); the
//! batcher remains as the wave shim's admission queue and now shares
//! the serve API's typed backpressure
//! ([`ServeError::QueueFull`](crate::serve::ServeError)).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::GenRequest;
use crate::serve::ServeError;

#[derive(Debug)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Queue bound: `push` fails with a typed error beyond it.
    pub capacity: usize,
    queue: VecDeque<GenRequest>,
}

impl Batcher {
    /// Unbounded admission queue (in-process tooling and benches).
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher::bounded(max_batch, max_wait, usize::MAX)
    }

    /// Bounded admission queue — the router's default, so backpressure
    /// surfaces to submitters instead of growing memory.
    pub fn bounded(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher {
        assert!(max_batch >= 1);
        assert!(capacity >= 1);
        Batcher { max_batch, max_wait, capacity, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: GenRequest) -> Result<(), ServeError> {
        if self.queue.len() >= self.capacity {
            return Err(ServeError::QueueFull { capacity: self.capacity });
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should a batch fire right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(oldest) => now.duration_since(oldest.submitted) >= self.max_wait,
            None => false,
        }
    }

    /// Pop up to `max_batch` requests if the policy says fire.
    pub fn next_batch(&mut self, now: Instant) -> Option<Vec<GenRequest>> {
        if !self.ready(now) {
            return None;
        }
        let n = self.queue.len().min(self.max_batch);
        Some(self.queue.drain(..n).collect())
    }

    /// Time until the deadline policy would fire (None if queue empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|oldest| {
            self.max_wait
                .saturating_sub(now.duration_since(oldest.submitted))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn fires_on_full_batch() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(0)).unwrap();
        let now = Instant::now();
        assert!(b.next_batch(now).is_none());
        b.push(req(1)).unwrap();
        let batch = b.next_batch(now).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fires_on_deadline_with_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(0)).unwrap();
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn respects_max_batch_when_overfull() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        for i in 0..5 {
            b.push(req(i)).unwrap();
        }
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 3);
        // FIFO order preserved.
        assert_eq!(batch[0].id, 0);
        assert_eq!(batch[1].id, 1);
    }

    #[test]
    fn empty_queue_never_ready() {
        let b = Batcher::new(1, Duration::from_millis(0));
        assert!(!b.ready(Instant::now()));
        assert!(b.time_to_deadline(Instant::now()).is_none());
        // An empty queue also reports no pending work after a drain.
        let mut b = Batcher::new(1, Duration::from_millis(0));
        b.push(req(0)).unwrap();
        assert!(b.next_batch(Instant::now()).is_some());
        assert!(!b.ready(Instant::now()));
        assert!(b.next_batch(Instant::now()).is_none());
    }

    #[test]
    fn deadline_countdown() {
        let mut b = Batcher::new(8, Duration::from_secs(10));
        b.push(req(0)).unwrap();
        let ttl = b.time_to_deadline(Instant::now()).unwrap();
        assert!(ttl <= Duration::from_secs(10));
        assert!(ttl >= Duration::from_secs(9));
    }

    #[test]
    fn exactly_at_deadline_fires_and_counts_down_to_zero() {
        let mut b = Batcher::new(8, Duration::from_secs(10));
        b.push(req(0)).unwrap();
        assert!(b.next_batch(Instant::now()).is_none(), "long deadline: not ready yet");
        // Reconstruct the exact deadline instant from the queued
        // request's own submission time.
        let mut b = Batcher::new(8, Duration::from_millis(250));
        let r = req(0);
        let at_deadline = r.submitted + Duration::from_millis(250);
        b.push(r).unwrap();
        assert_eq!(b.time_to_deadline(at_deadline), Some(Duration::ZERO));
        assert!(b.ready(at_deadline), ">= semantics: the deadline instant itself fires");
        assert_eq!(b.next_batch(at_deadline).unwrap().len(), 1);
        // Past the deadline the countdown saturates at zero.
        let mut b = Batcher::new(8, Duration::from_millis(1));
        let r = req(1);
        let late = r.submitted + Duration::from_secs(5);
        b.push(r).unwrap();
        assert_eq!(b.time_to_deadline(late), Some(Duration::ZERO));
    }

    #[test]
    fn bounded_queue_reports_queue_full() {
        use crate::serve::ServeError;
        let mut b = Batcher::bounded(4, Duration::from_secs(1), 2);
        b.push(req(0)).unwrap();
        b.push(req(1)).unwrap();
        assert_eq!(b.push(req(2)), Err(ServeError::QueueFull { capacity: 2 }));
        assert_eq!(b.pending(), 2, "rejected request is not enqueued");
        // Draining makes room again.
        let _ = b.next_batch(Instant::now() + Duration::from_secs(2));
        b.push(req(3)).unwrap();
    }
}
