//! Batch formation policy: a size-or-deadline admission queue.
//!
//! Requests accumulate until either `max_batch` are waiting (fire a
//! full batch) or the oldest request has waited `max_wait` (fire a
//! partial batch padded with idle slots). This is the classic
//! continuous-batching admission rule; wave execution is handled by
//! the engine.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::GenRequest;

#[derive(Debug)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
    queue: VecDeque<GenRequest>,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch >= 1);
        Batcher { max_batch, max_wait, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: GenRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should a batch fire right now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(oldest) => now.duration_since(oldest.submitted) >= self.max_wait,
            None => false,
        }
    }

    /// Pop up to `max_batch` requests if the policy says fire.
    pub fn next_batch(&mut self, now: Instant) -> Option<Vec<GenRequest>> {
        if !self.ready(now) {
            return None;
        }
        let n = self.queue.len().min(self.max_batch);
        Some(self.queue.drain(..n).collect())
    }

    /// Time until the deadline policy would fire (None if queue empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|oldest| {
            self.max_wait
                .saturating_sub(now.duration_since(oldest.submitted))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn fires_on_full_batch() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(0));
        let now = Instant::now();
        assert!(b.next_batch(now).is_none());
        b.push(req(1));
        let batch = b.next_batch(now).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fires_on_deadline_with_partial_batch() {
        let mut b = Batcher::new(8, Duration::from_millis(0));
        b.push(req(0));
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn respects_max_batch_when_overfull() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        for i in 0..5 {
            b.push(req(i));
        }
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 3);
        // FIFO order preserved.
        assert_eq!(batch[0].id, 0);
        assert_eq!(batch[1].id, 1);
    }

    #[test]
    fn empty_queue_never_ready() {
        let b = Batcher::new(1, Duration::from_millis(0));
        assert!(!b.ready(Instant::now()));
        assert!(b.time_to_deadline(Instant::now()).is_none());
    }

    #[test]
    fn deadline_countdown() {
        let mut b = Batcher::new(8, Duration::from_secs(10));
        b.push(req(0));
        let ttl = b.time_to_deadline(Instant::now()).unwrap();
        assert!(ttl <= Duration::from_secs(10));
        assert!(ttl >= Duration::from_secs(9));
    }
}
