//! Serving metrics: TTFT / TPOT / end-to-end latency / throughput —
//! the quantities behind the paper's "Decode" and "Forward" latency
//! columns (Tables 1/10) and the Speed@N multipliers (Table 2).

use crate::coordinator::request::GenResponse;
use crate::util::stats::{mean, median, quantile};

#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    pub ttft_s: Vec<f64>,
    pub tpot_s: Vec<f64>,
    pub total_s: Vec<f64>,
    pub tokens_out: u64,
    pub requests: u64,
    pub wall_s: f64,
}

impl ServeMetrics {
    pub fn record(&mut self, r: &GenResponse) {
        self.ttft_s.push(r.ttft_s);
        if r.tokens.len() > 1 {
            self.tpot_s.push(r.tpot_s());
        }
        self.total_s.push(r.total_s);
        self.tokens_out += r.tokens.len() as u64;
        self.requests += 1;
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.wall_s
    }

    pub fn summary(&self) -> String {
        if self.requests == 0 {
            return "no requests served".into();
        }
        format!(
            "requests={} tokens={} wall={:.2}s thpt={:.1} tok/s | \
             TTFT p50={:.1}ms p95={:.1}ms | TPOT p50={:.1}ms | e2e p50={:.1}ms mean={:.1}ms",
            self.requests,
            self.tokens_out,
            self.wall_s,
            self.throughput_tok_s(),
            median(&self.ttft_s) * 1e3,
            quantile(&self.ttft_s, 0.95) * 1e3,
            if self.tpot_s.is_empty() { 0.0 } else { median(&self.tpot_s) * 1e3 },
            median(&self.total_s) * 1e3,
            mean(&self.total_s) * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(n_tokens: usize, ttft: f64, total: f64) -> GenResponse {
        GenResponse {
            id: 0,
            prompt_len: 8,
            tokens: vec![1; n_tokens],
            ttft_s: ttft,
            total_s: total,
            worker: 0,
        }
    }

    #[test]
    fn records_and_summarizes() {
        let mut m = ServeMetrics::default();
        m.record(&resp(10, 0.1, 1.0));
        m.record(&resp(20, 0.2, 2.0));
        m.wall_s = 2.0;
        assert_eq!(m.requests, 2);
        assert_eq!(m.tokens_out, 30);
        assert!((m.throughput_tok_s() - 15.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("requests=2"), "{s}");
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.summary(), "no requests served");
        assert_eq!(m.throughput_tok_s(), 0.0);
    }

    #[test]
    fn single_token_skips_tpot() {
        let mut m = ServeMetrics::default();
        m.record(&resp(1, 0.1, 0.1));
        assert!(m.tpot_s.is_empty());
    }
}
