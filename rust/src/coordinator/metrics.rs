//! Serving metrics: TTFT / TPOT / per-token latency / end-to-end
//! latency / throughput — the quantities behind the paper's "Decode"
//! and "Forward" latency columns (Tables 1/10) and the Speed@N
//! multipliers (Table 2). Shared by the legacy wave coordinator, the
//! `serve` schedulers, and `bench serve`.

use crate::coordinator::request::GenResponse;
use crate::util::stats::{mean, quantile};

/// p50/p95/p99 summary of one latency distribution (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Percentiles {
    /// Compute from raw samples; all-zero for an empty slice.
    pub fn of(xs: &[f64]) -> Percentiles {
        if xs.is_empty() {
            return Percentiles::default();
        }
        Percentiles {
            p50: quantile(xs, 0.50),
            p95: quantile(xs, 0.95),
            p99: quantile(xs, 0.99),
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct ServeMetrics {
    /// Time to first token per request (queue wait + prefill), s.
    pub ttft_s: Vec<f64>,
    /// Per-request mean time-per-output-token over the decode phase, s.
    pub tpot_s: Vec<f64>,
    /// Streaming inter-token latencies (one sample per decode-step
    /// token, across all requests), s.
    pub token_lat_s: Vec<f64>,
    /// End-to-end latency per request, s.
    pub total_s: Vec<f64>,
    pub tokens_out: u64,
    pub requests: u64,
    pub failed: u64,
    pub wall_s: f64,
    /// Decode-pass lane-steps (one per lane per scheduler decode pass;
    /// prefill/TTFT tokens are excluded). The denominator of
    /// [`Self::tokens_per_step`].
    pub decode_steps: u64,
    /// Tokens committed by those lane-steps (1 per plain step, `m` per
    /// speculative step that emitted `m`). Non-speculative serving has
    /// `decode_tokens == decode_steps` exactly, so `tokens_per_step`
    /// is ≡ 1.0 off and > 1.0 iff speculation ever accepted a token.
    pub decode_tokens: u64,
    /// Draft tokens proposed by speculative steps (γ_eff per step).
    pub spec_proposed: u64,
    /// Draft tokens accepted (committed to the stream) by those steps.
    pub spec_accepted: u64,
}

impl ServeMetrics {
    /// Record a finished wave-API response.
    pub fn record(&mut self, r: &GenResponse) {
        self.record_finished(r.ttft_s, r.total_s, r.tokens.len());
    }

    /// Fold another tally into this one — the multi-replica router
    /// aggregates per-replica metrics this way. Sample vectors
    /// concatenate and counters add; `wall_s` takes the max (replicas
    /// step in lockstep under one driver clock, so summing walls would
    /// double-count time and deflate throughput N-fold).
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.ttft_s.extend_from_slice(&other.ttft_s);
        self.tpot_s.extend_from_slice(&other.tpot_s);
        self.token_lat_s.extend_from_slice(&other.token_lat_s);
        self.total_s.extend_from_slice(&other.total_s);
        self.tokens_out += other.tokens_out;
        self.requests += other.requests;
        self.failed += other.failed;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.decode_steps += other.decode_steps;
        self.decode_tokens += other.decode_tokens;
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
    }

    /// Record a finished request by its raw quantities (the serve-API
    /// path — no `GenResponse` envelope). TPOT is derived with the same
    /// definition as [`GenResponse::tpot_s`].
    pub fn record_finished(&mut self, ttft_s: f64, total_s: f64, tokens: usize) {
        self.ttft_s.push(ttft_s);
        self.total_s.push(total_s);
        if tokens > 1 {
            self.tpot_s.push((total_s - ttft_s) / (tokens - 1) as f64);
        }
        self.tokens_out += tokens as u64;
        self.requests += 1;
    }

    /// Record one streaming inter-token latency sample.
    pub fn record_token_latency(&mut self, s: f64) {
        self.token_lat_s.push(s);
    }

    pub fn record_failed(&mut self) {
        self.failed += 1;
    }

    /// Record one decode-pass lane-step that committed `tokens` tokens
    /// (1 for a plain step, the emitted count for a speculative step).
    pub fn record_decode(&mut self, tokens: usize) {
        self.decode_steps += 1;
        self.decode_tokens += tokens as u64;
    }

    /// Record one speculative verify: `proposed` draft tokens offered
    /// (γ_eff), `accepted` of them committed to the stream.
    pub fn record_speculation(&mut self, proposed: usize, accepted: usize) {
        self.spec_proposed += proposed as u64;
        self.spec_accepted += accepted as u64;
    }

    /// Fraction of proposed draft tokens the target accepted; 0.0
    /// before any speculative step ran.
    pub fn acceptance_rate(&self) -> f64 {
        if self.spec_proposed == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / self.spec_proposed as f64
    }

    /// Mean tokens committed per decode-pass lane-step. Exactly 1.0
    /// for non-speculative serving (every step commits one token), so
    /// any value > 1.0 certifies acceptance happened; 0.0 before any
    /// decode step ran.
    pub fn tokens_per_step(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decode_tokens as f64 / self.decode_steps as f64
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.tokens_out as f64 / self.wall_s
    }

    /// Time-to-first-token percentiles.
    pub fn ttft(&self) -> Percentiles {
        Percentiles::of(&self.ttft_s)
    }

    /// Streaming inter-token latency percentiles (falls back to the
    /// per-request TPOT samples when no streaming samples were taken —
    /// the wave path records only TPOT).
    pub fn token_latency(&self) -> Percentiles {
        if self.token_lat_s.is_empty() {
            Percentiles::of(&self.tpot_s)
        } else {
            Percentiles::of(&self.token_lat_s)
        }
    }

    /// End-to-end request latency percentiles.
    pub fn e2e(&self) -> Percentiles {
        Percentiles::of(&self.total_s)
    }

    pub fn summary(&self) -> String {
        if self.requests == 0 && self.failed == 0 {
            return "no requests served".into();
        }
        let ttft = self.ttft();
        let tok = self.token_latency();
        let e2e = self.e2e();
        let mut base = format!(
            "requests={} failed={} tokens={} wall={:.2}s thpt={:.1} tok/s | \
             TTFT p50={:.1}ms p95={:.1}ms p99={:.1}ms | \
             tok p50={:.1}ms p95={:.1}ms p99={:.1}ms | \
             e2e p50={:.1}ms mean={:.1}ms",
            self.requests,
            self.failed,
            self.tokens_out,
            self.wall_s,
            self.throughput_tok_s(),
            ttft.p50 * 1e3,
            ttft.p95 * 1e3,
            ttft.p99 * 1e3,
            tok.p50 * 1e3,
            tok.p95 * 1e3,
            tok.p99 * 1e3,
            e2e.p50 * 1e3,
            if self.total_s.is_empty() { 0.0 } else { mean(&self.total_s) * 1e3 },
        );
        if self.spec_proposed > 0 {
            base.push_str(&format!(
                " | spec accept={:.1}% tok/step={:.2}",
                self.acceptance_rate() * 100.0,
                self.tokens_per_step(),
            ));
        }
        base
    }
}

/// Tokens-within-SLO accounting — the router's headline number.
/// Goodput counts only the tokens of requests whose latencies met
/// their SLO class ([`SloClass::within`](crate::serve::request::SloClass::within)
/// decides; batch-class requests always qualify), so an overloaded
/// deployment that streams plenty of tokens *too late* scores low even
/// though raw throughput looks healthy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Goodput {
    /// Tokens of SLO-meeting requests.
    pub good_tokens: u64,
    /// All tokens, SLO met or not.
    pub total_tokens: u64,
    /// Requests that met their SLO.
    pub slo_met: u64,
    /// Requests that missed it.
    pub slo_missed: u64,
    /// Driver wall clock, seconds (set once by the harness).
    pub wall_s: f64,
}

impl Goodput {
    /// Record one finished request: its token count and whether its
    /// measured latencies met its SLO class.
    pub fn record(&mut self, tokens: usize, within_slo: bool) {
        self.total_tokens += tokens as u64;
        if within_slo {
            self.good_tokens += tokens as u64;
            self.slo_met += 1;
        } else {
            self.slo_missed += 1;
        }
    }

    /// Goodput: SLO-meeting tokens per second of wall time.
    pub fn goodput_tok_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.good_tokens as f64 / self.wall_s
    }

    /// Fraction of requests that met their SLO; 1.0 with no requests
    /// (an empty deployment violates nothing).
    pub fn attainment(&self) -> f64 {
        let n = self.slo_met + self.slo_missed;
        if n == 0 {
            return 1.0;
        }
        self.slo_met as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(n_tokens: usize, ttft: f64, total: f64) -> GenResponse {
        GenResponse {
            id: 0,
            prompt_len: 8,
            tokens: vec![1; n_tokens],
            ttft_s: ttft,
            total_s: total,
            worker: 0,
        }
    }

    #[test]
    fn records_and_summarizes() {
        let mut m = ServeMetrics::default();
        m.record(&resp(10, 0.1, 1.0));
        m.record(&resp(20, 0.2, 2.0));
        m.wall_s = 2.0;
        assert_eq!(m.requests, 2);
        assert_eq!(m.tokens_out, 30);
        assert!((m.throughput_tok_s() - 15.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("requests=2"), "{s}");
        assert!(s.contains("p99"), "{s}");
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.summary(), "no requests served");
        assert_eq!(m.throughput_tok_s(), 0.0);
        assert_eq!(m.ttft(), Percentiles::default());
        assert_eq!(m.token_latency(), Percentiles::default());
    }

    #[test]
    fn single_token_skips_tpot() {
        let mut m = ServeMetrics::default();
        m.record(&resp(1, 0.1, 0.1));
        assert!(m.tpot_s.is_empty());
    }

    #[test]
    fn speculation_counters_and_ratios() {
        let mut m = ServeMetrics::default();
        // Before anything runs the ratios are defined and zero.
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.tokens_per_step(), 0.0);
        // Three plain steps: tokens/step pinned at exactly 1.0.
        for _ in 0..3 {
            m.record_decode(1);
        }
        assert_eq!(m.tokens_per_step(), 1.0);
        assert!(!m.summary().contains("spec"), "no spec line without speculation");
        // One speculative step: γ=4 proposed, 3 accepted → 4 tokens.
        m.record_speculation(4, 3);
        m.record_decode(4);
        assert!((m.acceptance_rate() - 0.75).abs() < 1e-12);
        assert!((m.tokens_per_step() - 7.0 / 4.0).abs() < 1e-12);
        assert!(m.tokens_per_step() > 1.0, "acceptance must lift tokens/step above 1");
        m.record_finished(0.1, 0.5, 7);
        let s = m.summary();
        assert!(s.contains("spec accept=75.0%"), "{s}");
        assert!(s.contains("tok/step=1.75"), "{s}");
    }

    #[test]
    fn merge_concatenates_samples_and_maxes_wall() {
        let mut a = ServeMetrics::default();
        a.record_finished(0.1, 1.0, 10);
        a.wall_s = 2.0;
        a.record_decode(1);
        let mut b = ServeMetrics::default();
        b.record_finished(0.2, 2.0, 20);
        b.wall_s = 3.0;
        b.record_failed();
        b.record_speculation(4, 2);
        a.merge(&b);
        assert_eq!(a.requests, 2);
        assert_eq!(a.tokens_out, 30);
        assert_eq!(a.failed, 1);
        assert_eq!(a.ttft_s, vec![0.1, 0.2]);
        assert_eq!(a.wall_s, 3.0, "lockstep replicas share one wall clock");
        assert_eq!((a.spec_proposed, a.spec_accepted), (4, 2));
        // Throughput uses the merged (max) wall: 30 tokens / 3 s.
        assert!((a.throughput_tok_s() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn goodput_counts_only_slo_meeting_tokens() {
        let mut g = Goodput::default();
        assert_eq!(g.attainment(), 1.0, "empty deployment violates nothing");
        g.record(10, true);
        g.record(30, false);
        g.record(5, true);
        g.wall_s = 3.0;
        assert_eq!(g.good_tokens, 15);
        assert_eq!(g.total_tokens, 45);
        assert!((g.goodput_tok_s() - 5.0).abs() < 1e-9);
        assert!((g.attainment() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(Goodput::default().goodput_tok_s(), 0.0);
    }

    #[test]
    fn percentiles_of_known_distribution() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let p = Percentiles::of(&xs);
        assert_eq!(p.p50, 50.0);
        assert!((p.p95 - 95.0).abs() < 1e-9);
        assert!((p.p99 - 99.0).abs() < 1e-9);
        assert_eq!(Percentiles::of(&[]), Percentiles::default());
    }

    #[test]
    fn serve_path_recording() {
        let mut m = ServeMetrics::default();
        m.record_finished(0.2, 1.2, 11);
        m.record_token_latency(0.05);
        m.record_token_latency(0.07);
        m.record_failed();
        assert_eq!(m.requests, 1);
        assert_eq!(m.failed, 1);
        assert_eq!(m.tokens_out, 11);
        // TPOT derived: (1.2 - 0.2) / 10.
        assert!((m.tpot_s[0] - 0.1).abs() < 1e-12);
        // Streaming samples win over derived TPOT for token latency.
        assert!((m.token_latency().p50 - 0.06).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("failed=1"), "{s}");
    }
}
