//! Cross-replica coordination. The primary content is
//! [`router::ReplicaRouter`]: an SLO-aware front-end over N
//! independent `serve::ContinuousBatcher` replicas, routing each
//! request by prefix affinity + SLO-weighted load and reporting
//! goodput (tokens/s within SLO) — see ARCHITECTURE.md §8.
//!
//! The wave coordinator this module grew from is **deprecated as a
//! public serving API** in favor of [`crate::serve`] (the
//! request-lifecycle scheduler with continuous batching over
//! `AttentionSession`) and remains as a thin shim for driving the AOT
//! artifact executables:
//!
//! * [`request`] — request/response types
//! * [`batcher`] — admission queue + batch former (size/deadline
//!   policy), now bounded with typed `QueueFull` backpressure
//! * [`engine`] — generation engine: drives the AOT prefill/decode
//!   executables for one batch wave (sparse or dense KV caches live
//!   inside the executable's cache tensors); `run_wave` is deprecated
//! * [`router`] — [`ReplicaRouter`] (primary), plus the deprecated
//!   wave `Router` whose workers each own a PJRT runtime thread
//! * [`metrics`] — TTFT / per-token / p50-p95-p99 latency accounting
//!   plus [`metrics::Goodput`], shared with the serve schedulers and
//!   `bench serve`

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use batcher::Batcher;
pub use engine::Engine;
pub use metrics::{Goodput, Percentiles, ServeMetrics};
pub use request::{GenRequest, GenResponse};
pub use router::{tally_goodput, ReplicaRouter, RouteDecision, Router, RouterPolicy};
