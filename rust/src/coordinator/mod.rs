//! The serving coordinator — L3's systems contribution, shaped like a
//! miniature vLLM router/worker stack:
//!
//! * [`request`] — request/response types
//! * [`batcher`] — admission queue + batch former (size/deadline policy)
//! * [`engine`] — generation engine: drives the AOT prefill/decode
//!   executables for one batch wave (sparse or dense KV caches live
//!   inside the executable's cache tensors)
//! * [`router`] — multi-worker dispatch: each worker owns a PJRT
//!   runtime on its own thread; requests flow through the shared queue
//! * [`metrics`] — TTFT / TTNT / throughput accounting (the serving
//!   quantities Tables 1/10 report)

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use batcher::Batcher;
pub use engine::Engine;
pub use metrics::ServeMetrics;
pub use request::{GenRequest, GenResponse};
pub use router::Router;
