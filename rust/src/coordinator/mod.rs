//! The wave coordinator — **deprecated as a public serving API** in
//! favor of [`crate::serve`] (the request-lifecycle scheduler with
//! continuous batching over `AttentionSession`; see ARCHITECTURE.md
//! §Serving lifecycle). The wave path remains as a thin shim for
//! driving the AOT artifact executables:
//!
//! * [`request`] — request/response types
//! * [`batcher`] — admission queue + batch former (size/deadline
//!   policy), now bounded with typed `QueueFull` backpressure
//! * [`engine`] — generation engine: drives the AOT prefill/decode
//!   executables for one batch wave (sparse or dense KV caches live
//!   inside the executable's cache tensors); `run_wave` is deprecated
//! * [`router`] — multi-worker dispatch: each worker owns a PJRT
//!   runtime on its own thread; requests flow through the shared queue
//! * [`metrics`] — TTFT / per-token / p50-p95-p99 latency accounting,
//!   shared with the serve schedulers and `bench serve`

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;

pub use batcher::Batcher;
pub use engine::Engine;
pub use metrics::ServeMetrics;
pub use request::{GenRequest, GenResponse};
pub use router::Router;
