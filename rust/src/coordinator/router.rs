//! Multi-worker router: a shared admission queue feeding N engine
//! workers, each with its own PJRT runtime on its own OS thread (the
//! PJRT handles are !Send, so workers own their runtimes end-to-end —
//! the same process-per-device shape as a vLLM deployment, collapsed
//! onto threads for the CPU testbed).
//!
//! **Deprecated**: this is the wave-synchronous serving path — a
//! finished sequence holds its batch slot (and the executable's cache
//! tensors) until the slowest request in its wave completes, and the
//! response is one blocking `GenResponse`. The primary serving API is
//! [`crate::serve`]: a request-lifecycle scheduler with per-token
//! streaming, typed errors, and true continuous batching over
//! `AttentionSession`. The router remains for driving the AOT artifact
//! engines; its submit queue is now bounded, surfacing
//! [`ServeError::QueueFull`] backpressure like the serve API.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::{Engine, Sampling};
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::runtime::Runtime;
use crate::serve::ServeError;

struct Shared {
    queue: Mutex<(Batcher, bool)>, // (batcher, shutdown)
    cv: Condvar,
}

/// Router over N worker threads.
pub struct Router {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Configuration for the worker pool.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub artifact_dir: String,
    pub variant: String,
    pub workers: usize,
    pub batch_size: usize,
    pub max_wait: Duration,
    pub sampling_temperature: Option<f32>,
    /// Submit-queue bound: [`Router::submit`] returns
    /// [`ServeError::QueueFull`] beyond it instead of growing
    /// unboundedly.
    pub queue_capacity: usize,
}

impl Router {
    #[deprecated(
        note = "wave-synchronous serving path; use serve::ContinuousBatcher \
                (the request-lifecycle API) for new code"
    )]
    pub fn start(cfg: RouterConfig) -> Router {
        let shared = Arc::new(Shared {
            queue: Mutex::new((
                Batcher::bounded(cfg.batch_size, cfg.max_wait, cfg.queue_capacity),
                false,
            )),
            cv: Condvar::new(),
        });
        let workers = (0..cfg.workers)
            .map(|w| {
                let shared = shared.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("sfa-worker-{w}"))
                    .spawn(move || worker_loop(w, shared, cfg))
                    .expect("spawn worker")
            })
            .collect();
        Router {
            shared,
            workers,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit a prompt; returns the channel the response arrives on,
    /// or typed backpressure when the queue is at capacity.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> std::result::Result<Receiver<GenResponse>, ServeError> {
        let (tx, rx): (Sender<GenResponse>, Receiver<GenResponse>) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut req = GenRequest::new(id, prompt, max_new);
        req.reply = Some(tx);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.0.push(req)?;
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Graceful shutdown: drain the queue, join workers.
    pub fn shutdown(self) -> Result<()> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.1 = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers {
            w.join().expect("worker panicked")?;
        }
        Ok(())
    }
}

#[allow(deprecated)] // the worker drives the deprecated wave engine
fn worker_loop(worker: usize, shared: Arc<Shared>, cfg: RouterConfig) -> Result<()> {
    // Each worker owns its runtime (PJRT handles are thread-local).
    let runtime = Runtime::new(&cfg.artifact_dir)?;
    let sampling = match cfg.sampling_temperature {
        Some(t) => Sampling::Temperature(t),
        None => Sampling::Greedy,
    };
    let mut engine = Engine::new(
        &runtime,
        &cfg.variant,
        cfg.batch_size,
        sampling,
        0x5EED ^ worker as u64,
    )?;
    loop {
        // Wait for a fireable batch or shutdown.
        let batch = {
            let mut guard = shared.queue.lock().unwrap();
            loop {
                let now = Instant::now();
                if let Some(batch) = guard.0.next_batch(now) {
                    break Some(batch);
                }
                if guard.1 {
                    // Shutdown: drain stragglers regardless of deadline.
                    if guard.0.pending() > 0 {
                        let all = guard.0.next_batch(now + cfg.max_wait);
                        break all;
                    }
                    break None;
                }
                let wait = guard
                    .0
                    .time_to_deadline(now)
                    .unwrap_or(Duration::from_millis(50))
                    .min(Duration::from_millis(50));
                let (g, _) = shared
                    .cv
                    .wait_timeout(guard, wait.max(Duration::from_millis(1)))
                    .unwrap();
                guard = g;
            }
        };
        let Some(batch) = batch else { return Ok(()) };
        let responses = engine.run_wave(&batch, worker)?;
        for (req, resp) in batch.iter().zip(responses) {
            if let Some(tx) = &req.reply {
                let _ = tx.send(resp); // receiver may have gone away
            }
        }
    }
}
