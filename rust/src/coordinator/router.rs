//! Request routing across serving replicas.
//!
//! The primary content is [`ReplicaRouter`]: a front-end over N
//! independent [`ContinuousBatcher`] replicas — each with its own page
//! pool, prefix cache, and (at session level) its own
//! `SFA_THREADS`-sized threadpool — that places every request by a
//! deterministic cost model and reports **goodput** (tokens/s within
//! SLO) instead of raw throughput:
//!
//! * **Prefix affinity.** Each replica is probed with
//!   [`ContinuousBatcher::prefix_probe`] (a read-only radix-trie walk
//!   — it never touches a replica's LRU order or stats, so probing is
//!   free of admission side effects). A replica that already caches a
//!   long prefix of the prompt skips that much prefill work.
//! * **Load.** Queued + live requests on a replica delay a new
//!   arrival; interactive requests ([`SloClass::Interactive`]) weigh
//!   waiting more heavily than batch requests, which care mostly about
//!   landing where their prefix is warm.
//! * **Page pressure** tie-breaks, and ties resolve to the lowest
//!   replica index — routing is a pure function of (request, replica
//!   states), so a run's routing trace ([`ReplicaRouter::decisions`])
//!   is reproducible and the determinism tests can replay it.
//!
//! Streams are **bit-for-bit placement-independent**: every replica
//! runs the same deterministic [`ToyLm`](crate::serve::ToyLm) from the
//! same `model_seed`, and each request's sampler rng is derived from
//! `(model_seed, req.seed)` alone, so a request produces the identical
//! token stream on any replica, under any batch composition, and
//! across batch-lane preemptions (restart semantics regenerate the
//! same tokens). Routing therefore only ever moves *latency*, never
//! *content* — the property the router determinism tests pin.
//!
//! The wave-synchronous, PJRT-artifact [`Router`] this file used to be
//! about remains below as a deprecated shim for driving AOT artifact
//! engines.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::batcher::Batcher;
use crate::coordinator::engine::{Engine, Sampling};
use crate::coordinator::metrics::{Goodput, ServeMetrics};
use crate::coordinator::request::{GenRequest, GenResponse};
use crate::runtime::Runtime;
use crate::serve::scheduler::emit;
use crate::serve::{
    pages_reserved_tiered, ContinuousBatcher, FinishedRequest, RequestId, RequestState,
    Scheduler, ServeConfig, ServeConfigError, ServeError, ServeEvent, ServeRequest, StepReport,
};

/// How [`ReplicaRouter`] places requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// The cost model: prefix affinity − SLO-weighted load − page
    /// pressure (module docs). The default.
    SloAware,
    /// Ignore affinity, SLO class, and load: replica `i mod N` for the
    /// i-th submission. The baseline `sfa bench serve --replicas`
    /// measures the cost model against.
    RoundRobin,
}

/// Queueing-delay charge per in-flight request, in prefix-token
/// equivalents (one cached prefix token ≙ one token of prefill work
/// saved). Interactive requests pay more per queue position — they
/// would rather land on an idle replica than a warm busy one — while
/// batch requests chase warm caches.
const LOAD_TOKENS_INTERACTIVE: usize = 128;
const LOAD_TOKENS_BATCH: usize = 32;

/// One routing decision, in submission order — the trace that makes a
/// router run replayable (the determinism tests partition requests by
/// `replica` and re-run each partition on a standalone batcher).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    /// Router-global request id (what [`ReplicaRouter::take_finished`]
    /// reports).
    pub id: RequestId,
    /// Replica the request was placed on.
    pub replica: usize,
    /// Cached-prefix tokens the chosen replica's probe reported.
    pub affinity: usize,
    /// Whether the request carried an interactive SLO class.
    pub interactive: bool,
    /// `true` for a decision made by the admission-time re-routing
    /// pass ([`ReplicaRouter::step`]): the request was still queued on
    /// a page-pressured replica and migrated to the current cost-model
    /// winner before prefill started. A migrated request has two trace
    /// entries — the original placement and this one.
    pub migrated: bool,
}

/// A front-end router over N independent [`ContinuousBatcher`]
/// replicas (module docs). Synchronous and deterministic: `submit`
/// routes immediately against current replica states, `step` advances
/// every replica by one scheduling quantum.
pub struct ReplicaRouter {
    replicas: Vec<ContinuousBatcher>,
    policy: RouterPolicy,
    next_global: RequestId,
    rr_next: usize,
    /// Global id → (replica, replica-local id).
    fwd: BTreeMap<RequestId, (usize, RequestId)>,
    /// (replica, replica-local id) → global id.
    rev: BTreeMap<(usize, RequestId), RequestId>,
    decisions: Vec<RouteDecision>,
}

impl ReplicaRouter {
    /// Build `n` replicas of `cfg` (validated once, through the same
    /// [`ServeConfig::validate`] the builder uses). Every replica gets
    /// the full config — its own page pool, prefix cache, and draft
    /// session; nothing is shared between replicas except the router's
    /// maps.
    pub fn new(
        cfg: ServeConfig,
        n: usize,
        policy: RouterPolicy,
    ) -> Result<ReplicaRouter, ServeConfigError> {
        if n < 1 {
            return Err(ServeConfigError("replicas must be >= 1".into()));
        }
        cfg.validate()?;
        Ok(ReplicaRouter {
            replicas: (0..n).map(|_| ContinuousBatcher::new(cfg)).collect(),
            policy,
            next_global: 0,
            rr_next: 0,
            fwd: BTreeMap::new(),
            rev: BTreeMap::new(),
            decisions: Vec::new(),
        })
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// The routing trace so far, in submission order.
    pub fn decisions(&self) -> &[RouteDecision] {
        &self.decisions
    }

    /// Score every replica for `req` and pick the best. Returns
    /// `(replica, affinity)`. Pure: reads replica state, mutates
    /// nothing (the round-robin cursor advances in `submit`).
    fn route(&self, req: &ServeRequest) -> (usize, usize) {
        match self.policy {
            RouterPolicy::RoundRobin => {
                let r = self.rr_next % self.replicas.len();
                (r, self.replicas[r].prefix_probe(&req.prompt))
            }
            RouterPolicy::SloAware => {
                let load_w = if req.slo.is_interactive() {
                    LOAD_TOKENS_INTERACTIVE
                } else {
                    LOAD_TOKENS_BATCH
                };
                let mut best: Option<(i64, usize, usize)> = None; // (score, replica, affinity)
                for (i, rep) in self.replicas.iter().enumerate() {
                    let affinity = rep.prefix_probe(&req.prompt);
                    let inflight = rep.queued() + rep.live();
                    let heads = rep.config().heads.max(1);
                    // Tokens-equivalent score: cached prefix saved,
                    // minus queueing delay, minus a small page-pressure
                    // tie-break (cached tokens ≈ pages/heads·page_size;
                    // damped so it never outvotes a real affinity or
                    // load difference).
                    let pressure = rep.pages_in_use() / heads;
                    let score =
                        affinity as i64 - (inflight * load_w) as i64 - (pressure / 8) as i64;
                    // Strict > keeps ties at the lowest index.
                    if best.map_or(true, |(s, _, _)| score > s) {
                        best = Some((score, i, affinity));
                    }
                }
                let (_, replica, affinity) = best.expect("n >= 1 replicas");
                (replica, affinity)
            }
        }
    }

    /// Route and submit. The returned id is **router-global**; terminal
    /// records from [`Self::take_finished`] are remapped to it. A
    /// submission the chosen replica rejects (queue full, never-fits)
    /// surfaces the typed error and consumes nothing.
    pub fn submit(&mut self, req: ServeRequest) -> Result<RequestId, ServeError> {
        let (replica, affinity) = self.route(&req);
        let interactive = req.slo.is_interactive();
        let local = self.replicas[replica].submit(req)?;
        if self.policy == RouterPolicy::RoundRobin {
            self.rr_next += 1;
        }
        let id = self.next_global;
        self.next_global += 1;
        self.fwd.insert(id, (replica, local));
        self.rev.insert((replica, local), id);
        self.decisions.push(RouteDecision { id, replica, affinity, interactive, migrated: false });
        Ok(id)
    }

    /// A queued request is **page-pressured** on its replica when the
    /// replica's pages in use plus the request's own reservation exceed
    /// the per-group budget — it will sit behind the head-of-line block
    /// until live lanes drain. Conservative on purpose: `pages_in_use`
    /// under-counts reservations, so this only flags requests that are
    /// certainly not admitting this step.
    fn pressured(rep: &ContinuousBatcher, req: &ServeRequest) -> bool {
        let cfg = rep.config();
        let plen = req.prompt.len();
        let budget = req.max_new.min(cfg.max_seq.saturating_sub(plen));
        rep.pages_in_use() + pages_reserved_tiered(plen, budget, 0, cfg) > cfg.max_pages
    }

    /// Admission-time re-routing (SLO-aware policy only): every request
    /// still `Queued` on a page-pressured replica is re-scored against
    /// current replica states, and migrates — withdraw, resubmit,
    /// remap, new trace entry with `migrated: true` — when the cost
    /// model now prefers a different replica. Only queued requests
    /// move: they hold no lane, pages, or prefix borrow, and samplers
    /// derive from `(model_seed, req.seed)`, so migration re-places a
    /// stream without changing a single token. Round-robin never
    /// migrates (it is the placement-blind baseline).
    fn rebalance(&mut self) {
        if self.policy != RouterPolicy::SloAware {
            return;
        }
        let ids: Vec<RequestId> = self.fwd.keys().copied().collect();
        for id in ids {
            let (r0, l0) = self.fwd[&id];
            if !matches!(self.replicas[r0].state(l0), Some(RequestState::Queued)) {
                continue;
            }
            let (r1, affinity) = {
                let Some(req) = self.replicas[r0].queued_request(l0) else { continue };
                if !Self::pressured(&self.replicas[r0], req) {
                    continue;
                }
                self.route(req)
            };
            if r1 == r0 {
                continue;
            }
            // The target's queue must have room; its page/lane fit is
            // the admission pass's job, same as any fresh submission.
            if self.replicas[r1].queued() >= self.replicas[r1].config().queue_capacity {
                continue;
            }
            let Some(req) = self.replicas[r0].withdraw(l0) else { continue };
            let interactive = req.slo.is_interactive();
            emit(&req, ServeEvent::Migrated { id, from: r0, to: r1 });
            let local = self.replicas[r1]
                .submit(req)
                .expect("the origin replica accepted this request under the same config");
            self.rev.remove(&(r0, l0));
            self.fwd.insert(id, (r1, local));
            self.rev.insert((r1, local), id);
            self.decisions.push(RouteDecision {
                id,
                replica: r1,
                affinity,
                interactive,
                migrated: true,
            });
        }
    }

    /// Advance every replica by one scheduling quantum; the returned
    /// report is the field-wise sum across replicas.
    pub fn step(&mut self) -> StepReport {
        self.rebalance();
        let mut total = StepReport::default();
        for rep in &mut self.replicas {
            let r = rep.step();
            total.admitted += r.admitted;
            total.prefill_tokens += r.prefill_tokens;
            total.decoded_tokens += r.decoded_tokens;
            total.finished += r.finished;
            total.failed += r.failed;
            total.pages_freed += r.pages_freed;
            total.pages_pruned += r.pages_pruned;
            total.prefix_hits += r.prefix_hits;
            total.spec_accepted += r.spec_accepted;
            total.preempted += r.preempted;
            total.pages_demoted += r.pages_demoted;
            total.pages_promoted += r.pages_promoted;
            total.pages_in_use += r.pages_in_use;
            total.live += r.live;
        }
        total
    }

    pub fn has_work(&self) -> bool {
        self.replicas.iter().any(|r| r.has_work())
    }

    /// Lifecycle state of a global id (delegates to its replica).
    pub fn state(&self, id: RequestId) -> Option<&RequestState> {
        let (replica, local) = *self.fwd.get(&id)?;
        self.replicas[replica].state(local)
    }

    /// Drain terminal records from every replica, remapped to global
    /// ids and sorted by them (deterministic drain order regardless of
    /// which replica finished first).
    pub fn take_finished(&mut self) -> Vec<FinishedRequest> {
        let mut out = Vec::new();
        for (ri, rep) in self.replicas.iter_mut().enumerate() {
            for mut f in rep.take_finished() {
                let global = self
                    .rev
                    .remove(&(ri, f.id))
                    .expect("replica-local id was mapped at submit");
                self.fwd.remove(&global);
                f.id = global;
                out.push(f);
            }
        }
        out.sort_by_key(|f| f.id);
        out
    }

    /// Step until idle, then drain.
    pub fn run_to_completion(&mut self) -> Vec<FinishedRequest> {
        while self.has_work() {
            self.step();
        }
        self.take_finished()
    }

    /// Field-wise merge of every replica's metrics (wall time is the
    /// driver's to set — replicas step in lockstep, so per-replica
    /// walls would double-count).
    pub fn metrics(&self) -> ServeMetrics {
        let mut m = ServeMetrics::default();
        for rep in &self.replicas {
            m.merge(rep.metrics());
        }
        m
    }

    pub fn pages_in_use(&self) -> usize {
        self.replicas.iter().map(|r| r.pages_in_use()).sum()
    }

    pub fn queued(&self) -> usize {
        self.replicas.iter().map(|r| r.queued()).sum()
    }

    pub fn live(&self) -> usize {
        self.replicas.iter().map(|r| r.live()).sum()
    }

    /// Prefix-cache hit admissions summed across replicas.
    pub fn prefix_hits(&self) -> u64 {
        self.replicas.iter().map(|r| r.prefix_stats().hits).sum()
    }
}

/// Fold a drained batch of terminal records into a [`Goodput`] tally:
/// a request's tokens count as *good* iff its SLO class admits its
/// measured TTFT and derived TPOT (`(total − ttft) / (tokens − 1)`;
/// single-token requests have no decode phase and count by TTFT
/// alone). Batch-class tokens always count — their deadline is "ever".
/// Failed requests (no tokens) tally as an SLO miss with zero tokens.
pub fn tally_goodput(tally: &mut Goodput, finished: &[FinishedRequest]) {
    for f in finished {
        let n = f.tokens.len();
        let tpot = if n > 1 { (f.total_s - f.ttft_s) / (n - 1) as f64 } else { 0.0 };
        let within = n > 0 && f.slo.within(f.ttft_s, tpot);
        tally.record(n, within);
    }
}

// ---------------------------------------------------------------------
// Legacy wave-synchronous artifact router (deprecated shim).
// ---------------------------------------------------------------------

struct Shared {
    queue: Mutex<(Batcher, bool)>, // (batcher, shutdown)
    cv: Condvar,
}

/// **Deprecated** multi-worker router over the wave-synchronous PJRT
/// artifact engines: a shared admission queue feeding N workers, each
/// with its own PJRT runtime on its own OS thread (the PJRT handles
/// are !Send). A finished sequence holds its batch slot until the
/// slowest request in its wave completes and the response is one
/// blocking [`GenResponse`]. New code serves through [`ReplicaRouter`]
/// / [`crate::serve`].
pub struct Router {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<Result<()>>>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Configuration for the deprecated wave worker pool.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub artifact_dir: String,
    pub variant: String,
    pub workers: usize,
    pub batch_size: usize,
    pub max_wait: Duration,
    pub sampling_temperature: Option<f32>,
    /// Submit-queue bound: [`Router::submit`] returns
    /// [`ServeError::QueueFull`] beyond it instead of growing
    /// unboundedly.
    pub queue_capacity: usize,
}

impl Router {
    #[deprecated(
        note = "wave-synchronous artifact path; serve through ReplicaRouter over \
                serve::ContinuousBatcher replicas for new code"
    )]
    pub fn start(cfg: RouterConfig) -> Router {
        let shared = Arc::new(Shared {
            queue: Mutex::new((
                Batcher::bounded(cfg.batch_size, cfg.max_wait, cfg.queue_capacity),
                false,
            )),
            cv: Condvar::new(),
        });
        let workers = (0..cfg.workers)
            .map(|w| {
                let shared = shared.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("sfa-worker-{w}"))
                    .spawn(move || worker_loop(w, shared, cfg))
                    .expect("spawn worker")
            })
            .collect();
        Router {
            shared,
            workers,
            next_id: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Submit a prompt; returns the channel the response arrives on,
    /// or typed backpressure when the queue is at capacity.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
    ) -> std::result::Result<Receiver<GenResponse>, ServeError> {
        let (tx, rx): (Sender<GenResponse>, Receiver<GenResponse>) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut req = GenRequest::new(id, prompt, max_new);
        req.reply = Some(tx);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.0.push(req)?;
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Graceful shutdown: drain the queue, join workers.
    pub fn shutdown(self) -> Result<()> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.1 = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers {
            w.join().expect("worker panicked")?;
        }
        Ok(())
    }
}

#[allow(deprecated)] // the worker drives the deprecated wave engine
fn worker_loop(worker: usize, shared: Arc<Shared>, cfg: RouterConfig) -> Result<()> {
    // Each worker owns its runtime (PJRT handles are thread-local).
    let runtime = Runtime::new(&cfg.artifact_dir)?;
    let sampling = match cfg.sampling_temperature {
        Some(t) => Sampling::Temperature(t),
        None => Sampling::Greedy,
    };
    let mut engine = Engine::new(
        &runtime,
        &cfg.variant,
        cfg.batch_size,
        sampling,
        0x5EED ^ worker as u64,
    )?;
    loop {
        // Wait for a fireable batch or shutdown.
        let batch = {
            let mut guard = shared.queue.lock().unwrap();
            loop {
                let now = Instant::now();
                if let Some(batch) = guard.0.next_batch(now) {
                    break Some(batch);
                }
                if guard.1 {
                    // Shutdown: drain stragglers regardless of deadline.
                    if guard.0.pending() > 0 {
                        let all = guard.0.next_batch(now + cfg.max_wait);
                        break all;
                    }
                    break None;
                }
                let wait = guard
                    .0
                    .time_to_deadline(now)
                    .unwrap_or(Duration::from_millis(50))
                    .min(Duration::from_millis(50));
                let (g, _) = shared
                    .cv
                    .wait_timeout(guard, wait.max(Duration::from_millis(1)))
                    .unwrap();
                guard = g;
            }
        };
        let Some(batch) = batch else { return Ok(()) };
        let responses = engine.run_wave(&batch, worker)?;
        for (req, resp) in batch.iter().zip(responses) {
            if let Some(tx) = &req.reply {
                let _ = tx.send(resp); // receiver may have gone away
            }
        }
    }
}
