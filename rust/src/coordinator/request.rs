//! Request/response types crossing the coordinator boundary.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// A generation request (token-level API; tokenization is the
/// caller's concern in this synthetic-vocab reproduction).
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub submitted: Instant,
    /// Channel the response is delivered on.
    pub reply: Option<Sender<GenResponse>>,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest { id, prompt, max_new, submitted: Instant::now(), reply: None }
    }
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Time to first token (prefill + first sample), seconds.
    pub ttft_s: f64,
    /// Total request latency, seconds.
    pub total_s: f64,
    /// Which worker served it (router observability).
    pub worker: usize,
}

impl GenResponse {
    /// Time-per-output-token over the decode phase.
    pub fn tpot_s(&self) -> f64 {
        if self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.total_s - self.ttft_s) / (self.tokens.len() - 1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpot_math() {
        let r = GenResponse {
            id: 0,
            prompt_len: 4,
            tokens: vec![1, 2, 3, 4, 5],
            ttft_s: 0.2,
            total_s: 1.0,
            worker: 0,
        };
        assert!((r.tpot_s() - 0.2).abs() < 1e-12);
        let single = GenResponse { tokens: vec![1], ..r };
        assert_eq!(single.tpot_s(), 0.0);
    }
}
