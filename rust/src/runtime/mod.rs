//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! and executes them on the request path. Python is never involved —
//! the HLO text + weights.npz + manifest.json are the entire contract
//! (DESIGN.md §Artifact & manifest contract).
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json`
//! * [`client`] — PJRT client wrapper + lazy executable cache + typed
//!   literal helpers

pub mod client;
pub mod manifest;

pub use client::{HostTensor, Runtime};
pub use manifest::{Dtype, Entry, Manifest, TensorSpec, VariantManifest};
