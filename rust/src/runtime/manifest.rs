//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`). The manifest is the only metadata channel
//! between build-time Python and the runtime: input/output order,
//! shapes and dtypes of every compiled entry point, plus the parameter
//! flattening order.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

/// Shape + dtype + logical name of one tensor at an entry boundary.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
            dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
        })
    }
}

/// One compiled entry point (train_step, prefill_b4, ...).
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub batch: usize,
    pub seq: usize,
}

/// One compiled attention variant (dense, sfa_k8, ...).
#[derive(Debug, Clone)]
pub struct VariantManifest {
    pub name: String,
    pub params: Vec<TensorSpec>,
    pub weights: String,
    pub entries: BTreeMap<String, Entry>,
    /// Raw model-config JSON (vocab, d_model, sparsity, ...).
    pub config: Json,
}

impl VariantManifest {
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("variant {} has no entry {name:?} (have: {:?})",
                self.name, self.entries.keys().collect::<Vec<_>>()))
    }

    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config.get(key)?.as_usize()
    }
}

/// The whole artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: String,
    pub seed: u64,
    pub train_batch: usize,
    pub serve_batches: Vec<usize>,
    pub prefill_seq: usize,
    pub max_seq: usize,
    pub variants: BTreeMap<String, VariantManifest>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut variants = BTreeMap::new();
        for (name, vj) in j.get("variants")?.as_obj()? {
            let mut entries = BTreeMap::new();
            for (ename, ej) in vj.get("entries")?.as_obj()? {
                entries.insert(
                    ename.clone(),
                    Entry {
                        name: ename.clone(),
                        file: ej.get("file")?.as_str()?.to_string(),
                        inputs: ej
                            .get("inputs")?
                            .as_arr()?
                            .iter()
                            .map(TensorSpec::from_json)
                            .collect::<Result<_>>()?,
                        outputs: ej
                            .get("outputs")?
                            .as_arr()?
                            .iter()
                            .map(TensorSpec::from_json)
                            .collect::<Result<_>>()?,
                        batch: ej.get("batch")?.as_usize()?,
                        seq: ej.get("seq")?.as_usize()?,
                    },
                );
            }
            variants.insert(
                name.clone(),
                VariantManifest {
                    name: name.clone(),
                    params: vj
                        .get("params")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    weights: vj.get("weights")?.as_str()?.to_string(),
                    entries,
                    config: vj.get("config")?.clone(),
                },
            );
        }
        Ok(Manifest {
            dir,
            preset: j.get("preset")?.as_str()?.to_string(),
            seed: j.get("seed")?.as_f64()? as u64,
            train_batch: j.get("train_batch")?.as_usize()?,
            serve_batches: j
                .get("serve_batches")?
                .as_arr()?
                .iter()
                .map(|x| x.as_usize())
                .collect::<Result<_>>()?,
            prefill_seq: j.opt("prefill_seq").map(|x| x.as_usize()).transpose()?.unwrap_or(0),
            max_seq: j.opt("max_seq").map(|x| x.as_usize()).transpose()?.unwrap_or(0),
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantManifest> {
        self.variants.get(name).with_context(|| {
            format!(
                "no variant {name:?} in {:?} (have: {:?})",
                self.dir,
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "tiny", "seed": 42, "train_batch": 2,
      "serve_batches": [1], "prefill_seq": 64, "max_seq": 128,
      "variants": {
        "sfa_k4": {
          "config": {"vocab": 256, "d_model": 128, "sparsity": 4},
          "params": [
            {"name": "tok_emb", "shape": [256, 128], "dtype": "f32"}
          ],
          "weights": "sfa_k4/weights.npz",
          "entries": {
            "eval_step": {
              "file": "sfa_k4/eval_step.hlo.txt", "batch": 2, "seq": 128,
              "inputs": [
                {"name": "param:tok_emb", "shape": [256, 128], "dtype": "f32"},
                {"name": "tokens", "shape": [2, 128], "dtype": "i32"}
              ],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
            }
          }
        }
      }
    }"#;

    fn write_sample(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
    }

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("sfa_manifest_test");
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.train_batch, 2);
        let v = m.variant("sfa_k4").unwrap();
        assert_eq!(v.cfg_usize("sparsity").unwrap(), 4);
        let e = v.entry("eval_step").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[1].dtype, Dtype::I32);
        assert_eq!(e.inputs[1].numel(), 256);
        assert_eq!(e.outputs[0].shape.len(), 0);
    }

    #[test]
    fn missing_variant_is_informative() {
        let dir = std::env::temp_dir().join("sfa_manifest_test2");
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let err = format!("{:#}", m.variant("dense").unwrap_err());
        assert!(err.contains("sfa_k4"), "{err}");
    }

    #[test]
    fn missing_file_is_informative() {
        let err = format!(
            "{:#}",
            Manifest::load("/nonexistent/artifacts").unwrap_err()
        );
        assert!(err.contains("make artifacts"), "{err}");
    }
}
