//! PJRT client wrapper + executable cache + host-tensor interchange.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Compiled executables are cached per (variant, entry); the tuple
//! output of every entry is decomposed back into per-tensor literals so
//! step t's outputs can feed step t+1's inputs directly.
//!
//! A `Runtime` is deliberately single-threaded (!Send raw PJRT handles);
//! the coordinator gives each engine worker its own `Runtime`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::FromRawBytes;

use crate::runtime::manifest::{Dtype, Manifest, TensorSpec};

/// Host-side tensor for data interchange with the artifacts.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn numel(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], Vec<u8>) = match self {
            HostTensor::F32(d, s) => (
                xla::ElementType::F32,
                s,
                d.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            HostTensor::I32(d, s) => (
                xla::ElementType::S32,
                s,
                d.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Zero-initialized tensor matching a manifest spec (used for the
    /// AdamW m/v state and fresh KV caches).
    pub fn zeros(spec: &TensorSpec) -> HostTensor {
        match spec.dtype {
            Dtype::F32 => HostTensor::F32(vec![0.0; spec.numel()], spec.shape.clone()),
            Dtype::I32 => HostTensor::I32(vec![0; spec.numel()], spec.shape.clone()),
        }
    }
}

/// PJRT runtime over one artifact directory.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: RefCell<HashMap<(String, String), Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { manifest, client, exes: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an entry's executable.
    pub fn executable(
        &self,
        variant: &str,
        entry: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = (variant.to_string(), entry.to_string());
        if let Some(exe) = self.exes.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let e = self.manifest.variant(variant)?.entry(entry)?;
        let path = self.manifest.dir.join(&e.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let exe = self
            .client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .with_context(|| format!("compiling {variant}/{entry}"))?;
        eprintln!(
            "[runtime] compiled {variant}/{entry} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an entry with literal inputs; returns per-output literals
    /// (the single tuple output is decomposed).
    pub fn run(
        &self,
        variant: &str,
        entry: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let e = self.manifest.variant(variant)?.entry(entry)?;
        if args.len() != e.inputs.len() {
            bail!(
                "{variant}/{entry}: expected {} inputs, got {}",
                e.inputs.len(),
                args.len()
            );
        }
        let exe = self.executable(variant, entry)?;
        let result = exe.execute::<xla::Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != e.outputs.len() {
            bail!(
                "{variant}/{entry}: manifest promises {} outputs, executable returned {}",
                e.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Load the seeded initial weights for a variant, in manifest
    /// (sorted-name) order.
    pub fn load_weights(&self, variant: &str) -> Result<Vec<xla::Literal>> {
        let v = self.manifest.variant(variant)?;
        let path = self.manifest.dir.join(&v.weights);
        let mut named = xla::Literal::read_npz(
            path.to_str().context("non-utf8 path")?,
            &(),
        )?;
        // Keys are "NNNN|name": sort restores the flattening order.
        named.sort_by(|a, b| a.0.cmp(&b.0));
        if named.len() != v.params.len() {
            bail!(
                "{variant}: weights.npz has {} arrays, manifest lists {}",
                named.len(),
                v.params.len()
            );
        }
        for ((key, lit), spec) in named.iter().zip(&v.params) {
            let name = key.split_once('|').map(|x| x.1).unwrap_or(key);
            if name != spec.name {
                bail!("weights order mismatch: {name} vs {}", spec.name);
            }
            let dims: Vec<usize> = lit
                .array_shape()?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            if dims != spec.shape {
                bail!("{variant}/{name}: npz shape {dims:?} != manifest {:?}", spec.shape);
            }
        }
        Ok(named.into_iter().map(|(_, l)| l).collect())
    }

    /// Zero literals for a list of specs (opt-state / cache init).
    pub fn zeros(&self, specs: &[TensorSpec]) -> Result<Vec<xla::Literal>> {
        specs.iter().map(|s| HostTensor::zeros(s).to_literal()).collect()
    }

    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn host_tensor_roundtrip_i32() {
        let t = HostTensor::I32(vec![-1, 0, 7, 42], vec![4]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(3.5);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_f32().unwrap(), &[3.5]);
        assert!(back.shape().is_empty());
    }

    #[test]
    fn zeros_match_spec() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![3, 4],
            dtype: Dtype::I32,
        };
        let t = HostTensor::zeros(&spec);
        assert_eq!(t.numel(), 12);
        assert!(t.as_i32().unwrap().iter().all(|&x| x == 0));
    }

    // Integration tests against real artifacts live in rust/tests/.
}
