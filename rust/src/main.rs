//! `sfa` — the L3 coordinator binary.
//!
//! Subcommands:
//!   info                         artifact/manifest summary
//!   train   --variant V          train one variant, log losses
//!   serve   --requests N         synthetic serving load through the router
//!   exp     table1|table2|table3|fig8|table12     training experiments
//!   bench   fig1|fig3|fig5|fig6|table6|table7|table8|table9|table10
//!   analyze entropy|svd|memory   Fig 7 / Fig 11 / App J analyses

use anyhow::{bail, Result};

use sfa::bench::figures;
use sfa::coordinator::router::{Router, RouterConfig};
use sfa::coordinator::ServeMetrics;
use sfa::runtime::{HostTensor, Runtime};
use sfa::train::corpus::CorpusKind;
use sfa::train::experiments;
use sfa::train::trainer::Trainer;
use sfa::util::cli::Args;
use sfa::util::rng::Rng;

const USAGE: &str = "\
sfa — Sparse Feature Attention coordinator
USAGE: sfa <info|train|serve|exp|bench|analyze> [item] [--options]
  sfa info    [--artifacts DIR]
  sfa train   [--artifacts DIR] --variant sfa_k8 --steps 100 --lr 1e-3 --corpus zipf|niah
  sfa serve   [--artifacts DIR] --variant sfa_k8 --requests 16 --workers 2 --batch 4 --max-new 16
  sfa exp     table1|table2|table3|fig8|table12 [--steps N] [--artifacts DIR]
  sfa bench   fig1|fig3|fig5|fig6|table6|table7|table8|table9|table10 [--budget SECS]
  sfa analyze entropy|svd|memory [--variant V] [--steps N]
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv, 2)?;
    match args.command.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("exp") => cmd_exp(&args),
        Some("bench") => cmd_bench(&args),
        Some("analyze") => cmd_analyze(&args),
        _ => {
            print!("{USAGE}");
            bail!("unknown command {:?}", args.command)
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts_dir(args))?;
    let m = &rt.manifest;
    println!(
        "artifacts: {:?}\npreset={} seed={} train_batch={} serve_batches={:?} \
         prefill_seq={} max_seq={}",
        m.dir, m.preset, m.seed, m.train_batch, m.serve_batches, m.prefill_seq, m.max_seq
    );
    for (name, v) in &m.variants {
        let n_params: usize = v.params.iter().map(|p| p.numel()).sum();
        println!(
            "  {name}: {:.2}M params, entries: {}",
            n_params as f64 / 1e6,
            v.entries.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts_dir(args))?;
    let variant = args.str_or("variant", "sfa_k8");
    let steps = args.usize_or("steps", 100)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let corpus = CorpusKind::parse(&args.str_or("corpus", "zipf"))
        .ok_or_else(|| anyhow::anyhow!("--corpus must be zipf or niah"))?;
    let (trainer, report) = experiments::train_variant(
        &rt, &variant, corpus, steps, lr, args.u64_or("seed", 42)?, 10,
    )?;
    println!(
        "trained {variant}: final loss {:.4}, {:.0} tok/s, wall {:.1}s",
        report.final_loss, report.tokens_per_s, report.wall_s
    );
    let vocab = rt.manifest.variant(&variant)?.cfg_usize("vocab")?;
    let ppl = experiments::eval_ppl(&trainer, corpus, vocab, 4, 777)?;
    println!("held-out PPL: {ppl:.3}");
    if let Some(path) = args.get("checkpoint") {
        trainer.save_checkpoint(path)?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let variant = args.str_or("variant", "sfa_k8");
    let n_requests = args.usize_or("requests", 16)?;
    let workers = args.usize_or("workers", 2)?;
    let batch = args.usize_or("batch", 4)?;
    let max_new = args.usize_or("max-new", 16)?;
    let rt = Runtime::new(&dir)?;
    let vocab = rt.manifest.variant(&variant)?.cfg_usize("vocab")? as i32;
    let prefill_seq = rt.manifest.prefill_seq;
    drop(rt);

    let router = Router::start(RouterConfig {
        artifact_dir: dir,
        variant,
        workers,
        batch_size: batch,
        max_wait: std::time::Duration::from_millis(50),
        sampling_temperature: None,
    });
    let mut rng = Rng::new(args.u64_or("seed", 1)?);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|_| {
            let plen = rng.range(4, prefill_seq.min(64));
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
            router.submit(prompt, max_new)
        })
        .collect();
    let mut metrics = ServeMetrics::default();
    for rx in rxs {
        let resp = rx.recv()?;
        metrics.record(&resp);
    }
    metrics.wall_s = t0.elapsed().as_secs_f64();
    router.shutdown()?;
    println!("{}", metrics.summary());
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts_dir(args))?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let eval_batches = args.usize_or("eval-batches", 4)?;
    match args.command.get(1).map(|s| s.as_str()) {
        Some("table1") => {
            let steps = args.usize_or("steps", 200)?;
            let variants = args.str_list_or(
                "variants", &["dense", "sfa_k8", "sfa_k16", "short_d32"],
            );
            let (t, reports) = experiments::table1(&rt, &variants, steps, lr, eval_batches)?;
            t.print();
            if let Some(path) = args.get("loss-log") {
                let mut out = String::new();
                for r in &reports {
                    for (i, l) in r.losses.iter().enumerate() {
                        out.push_str(&format!("{}\t{}\t{}\n", r.variant, i, l));
                    }
                }
                std::fs::write(path, out)?;
            }
        }
        Some("table2") => {
            let steps = args.usize_or("steps", 300)?;
            let variants =
                args.str_list_or("variants", &["dense", "sfa_k2", "sfa_k8", "short_d16"]);
            let lengths = args.usize_list_or("lengths", &[64, 128, 256, 512])?;
            experiments::table2(&rt, &variants, steps, lr, &lengths, eval_batches)?.print();
        }
        Some("table3") => {
            let pre = args.usize_or("pre-steps", 200)?;
            let ft = args.usize_or("ft-steps", 60)?;
            let lam = args.f64_or("lambda", 1.0)? as f32;
            let variant = args.str_or("variant", "sfa_k8");
            experiments::table3(&rt, &variant, pre, ft, lr, lam, eval_batches)?.print();
        }
        Some("fig8") => {
            let steps = args.usize_or("steps", 150)?;
            let ks = args.usize_list_or("ks", &[2, 4, 8, 16])?;
            let (t, curves) = experiments::fig8(&rt, &ks, steps, lr, eval_batches)?;
            t.print();
            if let Some(path) = args.get("loss-log") {
                let mut out = String::new();
                for (k, losses) in &curves {
                    for (i, l) in losses.iter().enumerate() {
                        out.push_str(&format!("k{}\t{}\t{}\n", k, i, l));
                    }
                }
                std::fs::write(path, out)?;
                println!("loss curves written to {path} (Fig 10 data)");
            }
        }
        Some("table12") => {
            let steps = args.usize_or("steps", 200)?;
            let variants = args.str_list_or("variants", &["dense", "sfa_k8"]);
            let lengths = args.usize_list_or("lengths", &[64, 128, 256])?;
            experiments::table12(&rt, &variants, steps, lr, &lengths, eval_batches)?.print();
        }
        other => bail!("unknown experiment {other:?} — see README §Experiments"),
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let budget = args.f64_or("budget", 0.5)?;
    match args.command.get(1).map(|s| s.as_str()) {
        Some("fig1") => figures::fig1(args.usize_or("ctx", 131072)?).print(),
        Some("fig3") => figures::fig3(
            args.usize_or("ctx", 4096)?,
            args.usize_or("d", 128)?,
            &args.usize_list_or("ks", &[2, 8, 16, 32])?,
            budget,
        )
        .print(),
        Some("fig5") => figures::fig5(
            &args.usize_list_or("ctxs", &[1024, 4096, 16384, 65536, 262144])?,
            args.usize_or("d", 64)?,
            args.usize_or("k", 4)?,
        )
        .print(),
        Some("fig6") => {
            let (a, b) = figures::fig6(
                &args.usize_list_or("ctxs", &[512, 1024, 2048, 4096, 8192])?,
                args.usize_or("d", 128)?,
                args.usize_or("k", 8)?,
                budget,
            );
            a.print();
            b.print();
        }
        Some("table6") => {
            figures::table6(&args.usize_list_or("ctxs", &[8192, 16384, 32768, 65536])?).print()
        }
        Some("table7") => figures::table7(
            args.usize_or("ctx", 4096)?,
            args.usize_or("d", 128)?,
            args.usize_or("k", 8)?,
            budget,
        )
        .print(),
        Some("table8") => figures::table8(
            &args.usize_list_or("ctxs", &[1024, 4096, 8192, 16384, 32768, 65536])?,
            args.usize_or("d", 128)?,
            args.usize_or("k", 16)?,
            budget,
        )
        .print(),
        Some("table9") | Some("fig4") => figures::table9(
            &args.usize_list_or("ctxs", &[1024, 4096, 8192, 16384])?,
            &args.usize_list_or("dims", &[64, 128, 256])?,
            &args.usize_list_or("ks", &[2, 4, 8, 16, 32])?,
            budget,
        )
        .print(),
        Some("table10") => figures::table10_latency(
            args.usize_or("ctx", 4096)?,
            args.usize_or("d", 128)?,
            args.usize_or("k", 8)?,
            budget,
        )
        .print(),
        other => bail!("unknown bench target {other:?}"),
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    match args.command.get(1).map(|s| s.as_str()) {
        Some("memory") => {
            use sfa::sparse::memory::{memory_ratio, paper_ratio_approx, Widths};
            let mut t = sfa::bench::Table::new(
                "Appendix J — dense/CSR memory ratio (fp16/int8/int32 widths)",
                &["d", "k", "exact ratio", "2d/(3k+4)"],
            );
            for &d in &[64usize, 128, 256, 1024] {
                for &k in &[4usize, 8, 16, 32] {
                    if k >= d {
                        continue;
                    }
                    t.row(vec![
                        d.to_string(),
                        k.to_string(),
                        format!("{:.2}", memory_ratio(65536, d, k, Widths::PAPER)),
                        format!("{:.2}", paper_ratio_approx(d, k)),
                    ]);
                }
            }
            t.print();
        }
        Some(which @ ("entropy" | "svd")) => {
            let rt = Runtime::new(artifacts_dir(args))?;
            let variant = args.str_or("variant", "sfa_k8");
            let steps = args.usize_or("steps", 50)?;
            let k = args.usize_or("k", 8)?;
            // Short training run so the activations are "trained", then
            // pull per-layer Q/K via the qk_acts artifact.
            let (trainer, _) = experiments::train_variant(
                &rt, &variant, CorpusKind::Zipf, steps,
                args.f64_or("lr", 1e-3)? as f32, 42, 0,
            )?;
            let acts = qk_acts(&rt, &trainer, &variant)?;
            if which == "entropy" {
                let mut t = sfa::bench::Table::new(
                    &format!(
                        "Fig 7 — top-{k} selection entropy per (layer, head), \
                         {variant}, {steps} steps"
                    ),
                    &["layer", "tensor", "per-head entropy"],
                );
                for (layer, (qs, ks_)) in acts.iter().enumerate() {
                    for (name, heads) in [("Q", qs), ("K", ks_)] {
                        let es: Vec<String> = heads
                            .iter()
                            .map(|m| {
                                format!(
                                    "{:.3}",
                                    sfa::analysis::entropy::selection_entropy(m, k)
                                )
                            })
                            .collect();
                        t.row(vec![layer.to_string(), name.into(), es.join(" ")]);
                    }
                }
                t.print();
            } else {
                let tau = args.f64_or("tau", 0.9)? as f32;
                let mut t = sfa::bench::Table::new(
                    &format!("Fig 11 — effective rank (τ={tau}) per (layer, head), {variant}"),
                    &["layer", "tensor", "d_head", "per-head effective rank"],
                );
                for (layer, (qs, ks_)) in acts.iter().enumerate() {
                    for (name, heads) in [("Q", qs), ("K", ks_)] {
                        let rs: Vec<String> = heads
                            .iter()
                            .map(|m| sfa::analysis::svd::effective_rank(m, tau).to_string())
                            .collect();
                        t.row(vec![
                            layer.to_string(),
                            name.into(),
                            heads[0].cols.to_string(),
                            rs.join(" "),
                        ]);
                    }
                }
                t.print();
            }
        }
        other => bail!("unknown analysis {other:?}"),
    }
    Ok(())
}

/// Run the qk_acts artifact on a fresh corpus batch and split the
/// outputs into per-layer, per-head matrices of shape (B·S, dq).
fn qk_acts(
    rt: &Runtime,
    trainer: &Trainer,
    variant: &str,
) -> Result<Vec<(Vec<sfa::util::matrix::Matrix>, Vec<sfa::util::matrix::Matrix>)>> {
    use sfa::util::matrix::Matrix;
    let v = rt.manifest.variant(variant)?;
    let e = v.entry("qk_acts")?;
    let vocab = v.cfg_usize("vocab")?;
    let (b, s) = (e.batch, e.seq);
    let mut corpus = sfa::train::ZipfCorpus::new(vocab, 123);
    let tokens = corpus.batch(b, s);
    let mut args_: Vec<xla::Literal> = Vec::new();
    for p in trainer.params() {
        args_.push(sfa::train::trainer::clone_literal(p)?);
    }
    args_.push(HostTensor::I32(tokens, vec![b, s]).to_literal()?);
    let outs = rt.run(variant, "qk_acts", &args_)?;
    // Outputs alternate q, k per layer; each is (B, H, S, dq).
    let mut layers = Vec::new();
    let mut it = outs.iter();
    while let (Some(q), Some(k)) = (it.next(), it.next()) {
        let mut pair = (Vec::new(), Vec::new());
        for (lit, dst) in [(q, &mut pair.0), (k, &mut pair.1)] {
            let t = HostTensor::from_literal(lit)?;
            let shape = t.shape().to_vec();
            let (bb, h, ss, dq) = (shape[0], shape[1], shape[2], shape[3]);
            let data = t.as_f32()?;
            for head in 0..h {
                let mut m = Matrix::zeros(bb * ss, dq);
                for batch in 0..bb {
                    for pos in 0..ss {
                        let src = ((batch * h + head) * ss + pos) * dq;
                        let dst_row = batch * ss + pos;
                        m.row_mut(dst_row).copy_from_slice(&data[src..src + dq]);
                    }
                }
                dst.push(m);
            }
        }
        layers.push(pair);
    }
    Ok(layers)
}
