//! `sfa` — the L3 coordinator binary.
//!
//! Subcommands:
//!   info                         artifact/manifest summary
//!   train   --variant V          train one variant, log losses
//!   serve   --requests N         request-lifecycle serving (continuous batching
//!                                over AttentionSession; --legacy for the old
//!                                artifact-driven wave router)
//!   exp     table1|table2|table3|fig8|table12     training experiments
//!   bench   fig1|fig3|fig5|fig6|table6|table7|table8|table9|table10|engines|serve
//!   analyze entropy|svd|memory|session   Fig 7 / Fig 11 / App J / session demo
//!
//! Attention engines are addressed by registry spec strings
//! (`--engine "sfa:k=8,bq=64,bk=64"`, `--engines "a;b;c"`); every
//! `bench` invocation also writes the measurements it took to
//! BENCH_attention.json (override with --bench-json PATH), and
//! `bench serve` writes the continuous-vs-wave scheduling comparison
//! to BENCH_serve.json (override with --serve-json PATH); `bench serve
//! --replicas N` writes the SLO-aware multi-replica router comparison
//! to BENCH_serve_router.json.

use anyhow::{bail, Result};

use sfa::bench::figures;
use sfa::bench::serve_bench::{self, ServeBenchConfig};
use sfa::coordinator::router::{Router, RouterConfig};
use sfa::coordinator::ServeMetrics;
use sfa::runtime::{HostTensor, Runtime};
use sfa::bench::serve_bench::PrefixBenchConfig;
use sfa::serve::{
    ContinuousBatcher, KvTierCfg, PagedKvPolicy, PrefixCacheConfig, Scheduler, ServeConfig,
    SloClass, SpeculateConfig, WaveScheduler,
};
use sfa::train::corpus::CorpusKind;
use sfa::train::experiments;
use sfa::train::trainer::Trainer;
use sfa::util::cli::Args;
use sfa::util::rng::Rng;

const USAGE: &str = "\
sfa — Sparse Feature Attention coordinator
USAGE: sfa <info|train|serve|exp|bench|analyze> [item] [--options]
  sfa info    [--artifacts DIR]
  sfa train   [--artifacts DIR] --variant sfa_k8 --steps 100 --lr 1e-3 --corpus zipf|niah
  sfa serve   --requests 16 --scheduler continuous|wave --engines \"SPEC;SPEC\"
              --prompt-min 16 --prompt-max 256 --max-new-min 8 --max-new-max 32
              --lanes 8 --page-size 16 --max-pages 4096 [--policy KVPOLICY]
              [--prefix-cache [--prefix-pages 1024]] [--prefill-chunk N]
              [--speculate draft=SPEC [--gamma 4]]
              [--kv-tier tier:cold_after=N[,policy=lru|h2o]]
              [--sampler-seed N] [--temperature T]
              (synthetic load, request-lifecycle API over AttentionSession —
              no artifacts needed; --policy enables KV eviction with
              policy-budget admission, --prefix-cache enables radix
              prompt-prefix sharing across requests, --prefill-chunk N
              ingests prompts N tokens per step so long prefills
              interleave with decode (0 = monolithic), --speculate runs
              draft-and-verify decoding with γ draft tokens per step;
              --sampler-seed seeds request i's sampler with N+i and
              --temperature switches the workload to stochastic sampling)
  sfa serve   --legacy [--artifacts DIR] --variant sfa_k8 --requests 16 --workers 2
              --batch 4 --max-new 16 --queue-capacity 1024   (deprecated wave router)
  sfa exp     table1|table2|table3|fig8|table12 [--steps N] [--artifacts DIR]
  sfa bench   fig1|fig3|fig5|fig6|table6|table7|table8|table9|table10|engines
              [--budget SECS] [--engine SPEC] [--engines \"SPEC;SPEC;...\"]
              [--bench-json PATH]   (writes BENCH_attention.json)
  sfa bench   serve [--requests 32] [--prompt-min 32] [--prompt-max 1024]
              [--max-new-min 8] [--max-new-max 96] [--engines \"SPEC;...\"]
              [--policies \"none;h2o;snapkv;quest\"] [--lanes 32]
              [--serve-json PATH]   (wave vs continuous KV-policy sweep,
              writes BENCH_serve.json)
  sfa bench   serve --prefix-cache [--system-prompt N] [--prefix-pages 1024]
              (cold vs radix prefix cache on a repeated-system-prompt
              workload: hit rate, TTFT gain, bit-identical streams —
              recorded in BENCH_serve.json)
  sfa bench   serve --speculate draft=SPEC [--gamma 4] [--sampler-seed N]
              [--temperature T]   (plain vs draft-and-verify speculative
              decoding on the same workload: acceptance rate, tokens/step,
              bit-identical streams — writes BENCH_serve_spec.json)
  sfa bench   serve --prefill-chunk [N] [--chunks 0,64,256,1024]
              [--long-prompt 4096] [--long-max-new 8] [--decode-lanes 8]
              [--decode-prompt 16] [--decode-max-new 32]
              (chunked-prefill interference: one long prompt vs short
              decode lanes per chunk size; decode-lane TTFT p50/p95,
              bit-identical streams — recorded in BENCH_serve.json)
  sfa bench   serve --kv-tier tier:cold_after=N[,policy=lru|h2o]
              (fp32 vs int8 cold-page tier on the same workload:
              demotions, effective-capacity gain from half-cost cold
              pages, achieved concurrency at fixed --max-pages, dequant
              error bound, bit-identical streams when the tier never
              fires — writes BENCH_serve_tiered.json)
  sfa bench   serve --replicas N [--slo interactive:ttft_ms=250,tpot_ms=50]
              [--interactive-frac 0.5] [--system-prompts 4]
              [--system-prompt-len 64] [--burst-len 8] [--burst-rate 2.0]
              [--burst-gap 12] [--tail-alpha 1.2] [--prefix-pages 1024]
              (SLO-aware ReplicaRouter vs round-robin over N replicas on a
              trace-driven workload — bursty on-off arrivals, heavy-tailed
              batch prompts, shared system prompts; reports goodput
              (tokens/s within SLO), interactive TTFT p50/p95, preemptions,
              bit-identical streams — writes BENCH_serve_router.json)
  sfa analyze entropy|svd|memory|session [--variant V] [--steps N] [--engine SPEC]
engine SPECs: dense | flash_dense:bq=64,bk=64
              | sfa:k=8,bq=64,bk=64[,skip=on[,thresh=T|,mass=EPS]]
              | sfa_ref:k=8
              | window:w=256,scorer=sfa_k8 | lowrank:r=16 | mla:r=16
              | performer:m=128 | quant:scorer=sfa_k8
KV policies:  none | h2o[:budget=128,recent=16] | snapkv[:budget=128,recent=16]
              | quest[:budget=128]
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv, 2)?;
    match args.command.first().map(|s| s.as_str()) {
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("exp") => cmd_exp(&args),
        Some("bench") => cmd_bench(&args),
        Some("analyze") => cmd_analyze(&args),
        _ => {
            print!("{USAGE}");
            bail!("unknown command {:?}", args.command)
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts_dir(args))?;
    let m = &rt.manifest;
    println!(
        "artifacts: {:?}\npreset={} seed={} train_batch={} serve_batches={:?} \
         prefill_seq={} max_seq={}",
        m.dir, m.preset, m.seed, m.train_batch, m.serve_batches, m.prefill_seq, m.max_seq
    );
    for (name, v) in &m.variants {
        let n_params: usize = v.params.iter().map(|p| p.numel()).sum();
        println!(
            "  {name}: {:.2}M params, entries: {}",
            n_params as f64 / 1e6,
            v.entries.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts_dir(args))?;
    let variant = args.str_or("variant", "sfa_k8");
    let steps = args.usize_or("steps", 100)?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let corpus = CorpusKind::parse(&args.str_or("corpus", "zipf"))
        .ok_or_else(|| anyhow::anyhow!("--corpus must be zipf or niah"))?;
    let (trainer, report) = experiments::train_variant(
        &rt, &variant, corpus, steps, lr, args.u64_or("seed", 42)?, 10,
    )?;
    println!(
        "trained {variant}: final loss {:.4}, {:.0} tok/s, wall {:.1}s",
        report.final_loss, report.tokens_per_s, report.wall_s
    );
    let vocab = rt.manifest.variant(&variant)?.cfg_usize("vocab")?;
    let ppl = experiments::eval_ppl(&trainer, corpus, vocab, 4, 777)?;
    println!("held-out PPL: {ppl:.3}");
    if let Some(path) = args.get("checkpoint") {
        trainer.save_checkpoint(path)?;
        println!("checkpoint written to {path}");
    }
    Ok(())
}

/// Assemble the serve-stack geometry/policy config from CLI options
/// through [`ServeConfig::builder`] — construction-time validation
/// (geometry, budgets, mutual exclusions) lives in one place and
/// surfaces here as the builder's typed error.
fn serve_config(args: &Args) -> Result<ServeConfig> {
    let kv_policy = match args.get("policy") {
        Some(s) => PagedKvPolicy::parse(s).map_err(|e| anyhow::anyhow!("--policy: {e}"))?,
        None => None,
    };
    let prefix_cache = if args.has("prefix-cache") {
        Some(PrefixCacheConfig { max_pages: args.usize_or("prefix-pages", 1024)? })
    } else {
        None
    };
    let speculate = match args.get("speculate") {
        Some(s) => Some(
            SpeculateConfig::parse(s, args.usize_or("gamma", 4)?)
                .map_err(|e| anyhow::anyhow!("--speculate: {}", e.0))?,
        ),
        None => None,
    };
    let kv_tier = match args.get("kv-tier") {
        Some(s) => Some(KvTierCfg::parse(s).map_err(|e| anyhow::anyhow!("--kv-tier: {e}"))?),
        None => None,
    };
    ServeConfig::builder()
        .heads(args.usize_or("heads", 4)?)
        .d(args.usize_or("d", 32)?)
        .vocab(args.usize_or("vocab", 64)?)
        .page_size(args.usize_or("page-size", 16)?)
        .max_pages(args.usize_or("max-pages", 4096)?)
        .max_lanes(args.usize_or("lanes", 8)?)
        .queue_capacity(args.usize_or("queue-capacity", 4096)?)
        .max_seq(args.usize_or("max-seq", 4096)?)
        .model_seed(args.u64_or("model-seed", 0x5FA)?)
        .kv_policy(kv_policy)
        .prefix_cache(prefix_cache)
        .prefill_chunk(args.usize_or("prefill-chunk", 0)?)
        .speculate(speculate)
        .kv_tier(kv_tier)
        .build()
        .map_err(|e| anyhow::anyhow!("serve config: {e}"))
}

/// Assemble a serve workload from CLI options (shared by `sfa serve`
/// and `sfa bench serve`; defaults differ per caller).
fn serve_workload_cfg(
    args: &Args,
    requests: usize,
    prompt_range: (usize, usize),
    max_new_range: (usize, usize),
) -> Result<ServeBenchConfig> {
    let serve = serve_config(args)?;
    let cfg = ServeBenchConfig {
        requests: args.usize_or("requests", requests)?,
        prompt_min: args.usize_or("prompt-min", prompt_range.0)?,
        prompt_max: args.usize_or("prompt-max", prompt_range.1)?,
        max_new_min: args.usize_or("max-new-min", max_new_range.0)?,
        max_new_max: args.usize_or("max-new-max", args.usize_or("max-new", max_new_range.1)?)?,
        engines: parse_spec_list(&args.str_or("engines", &args.str_or("engine", "sfa:k=8")))?,
        // `bench serve` replaces this with the --policies sweep; plain
        // `sfa serve` drives one scheduler straight from `serve`.
        policies: vec![serve.kv_policy],
        prefix: None,
        chunked: None,
        speculate: serve.speculate,
        router: None,
        tiered: None,
        sampler_seed: args.u64_or("sampler-seed", 0)?,
        temperature: match args.get("temperature") {
            Some(_) => Some(args.f64_or("temperature", 0.0)? as f32),
            None => None,
        },
        serve,
        seed: args.u64_or("seed", 42)?,
    };
    if cfg.requests == 0 || cfg.engines.is_empty() {
        bail!("need at least one request and one engine spec");
    }
    if let Some(t) = cfg.temperature {
        if !(t > 0.0) {
            bail!("--temperature must be > 0 (omit the flag for greedy decoding)");
        }
    }
    // A draft spec must be valid against *every* workload engine, or
    // submission would reject requests mid-drive.
    if let Some(sp) = &cfg.serve.speculate {
        for e in &cfg.engines {
            let target = sfa::attention::registry::parse_spec(e)?;
            sfa::attention::registry::validate_draft_spec(&sp.draft, &target)
                .map_err(|er| anyhow::anyhow!("--speculate: {}", er.0))?;
        }
    }
    if cfg.prompt_min < 1 || cfg.prompt_min > cfg.prompt_max {
        bail!("--prompt-min must be in 1..=--prompt-max");
    }
    if cfg.max_new_min < 1 || cfg.max_new_min > cfg.max_new_max {
        bail!("--max-new-min must be in 1..=--max-new-max");
    }
    if cfg.prompt_max + cfg.max_new_max > cfg.serve.max_seq {
        bail!(
            "--prompt-max {} + --max-new-max {} exceeds --max-seq {}",
            cfg.prompt_max,
            cfg.max_new_max,
            cfg.serve.max_seq
        );
    }
    if cfg.requests > cfg.serve.queue_capacity {
        bail!(
            "--requests {} exceeds --queue-capacity {} (the driver submits the whole \
             workload up front)",
            cfg.requests,
            cfg.serve.queue_capacity
        );
    }
    // Worst case over the workload distribution: the largest request
    // must fit an empty cache, or submission would reject it.
    check_workload_fits(&cfg, cfg.serve.kv_policy)?;
    Ok(cfg)
}

/// Bail unless the workload's largest request fits an empty cache
/// under `policy` — the same formulas submit-time validation rejects
/// by: the policy-budget steady state plus the prefill-time transient
/// of the longest prompt. Callers re-check per scheduler/policy
/// actually run (the wave baseline strips any policy; `bench serve`
/// sweeps several).
fn check_workload_fits(cfg: &ServeBenchConfig, policy: Option<PagedKvPolicy>) -> Result<()> {
    let serve = ServeConfig { kv_policy: policy, ..cfg.serve };
    let worst = sfa::serve::pages_reserved(
        cfg.prompt_max,
        cfg.max_new_max.min(serve.max_seq - cfg.prompt_max),
        &serve,
    )
    .max(sfa::serve::pages_needed(cfg.prompt_max, 0, serve.heads, serve.page_size));
    if worst > serve.max_pages {
        bail!(
            "a (prompt {}, max_new {}) request needs up to {} KV pages under policy {} \
             but --max-pages is {}",
            cfg.prompt_max,
            cfg.max_new_max,
            worst,
            serve_bench::policy_label(&policy),
            serve.max_pages
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("legacy") {
        return cmd_serve_legacy(args);
    }
    let mut cfg = serve_workload_cfg(args, 16, (16, 256), (8, 32))?;
    let which = args.str_or("scheduler", "continuous");
    if which == "wave"
        && (cfg.serve.kv_policy.is_some()
            || cfg.serve.prefix_cache.is_some()
            || cfg.serve.prefill_chunk > 0
            || cfg.serve.speculate.is_some()
            || cfg.serve.kv_tier.is_some())
    {
        // The wave baseline ignores every batcher-only knob (worst-case,
        // cold-prefill, one-token-per-step semantics); strip them through
        // the shared helper and re-validate so submission can't reject
        // what the policy-aware pre-check admitted.
        cfg.serve = cfg.serve.strip_incompatible();
        check_workload_fits(&cfg, None)?;
    }
    let reqs = serve_bench::workload(&cfg);
    let policy = serve_bench::policy_label(&cfg.serve.kv_policy);
    let stats = match which.as_str() {
        "continuous" => {
            let mut s = ContinuousBatcher::try_new(cfg.serve)
                .map_err(|e| anyhow::anyhow!("serve config: {e}"))?;
            let stats = serve_bench::drive(&mut s, "continuous", &policy, &reqs);
            if cfg.serve.speculate.is_some() {
                println!(
                    "speculate: accept={:.1}% tokens/step={:.2}",
                    s.metrics().acceptance_rate() * 100.0,
                    s.metrics().tokens_per_step(),
                );
            }
            stats
        }
        "wave" => {
            let mut s = WaveScheduler::try_new(cfg.serve)
                .map_err(|e| anyhow::anyhow!("serve config: {e}"))?;
            serve_bench::drive(&mut s, "wave", "none", &reqs)
        }
        other => bail!("--scheduler must be continuous or wave, got {other:?}"),
    };
    println!(
        "scheduler={} policy={} requests={} failed={} steps={} peak_pages={} \
         pruned_pages={} mean_live={:.2} peak_live={}",
        stats.scheduler,
        stats.policy,
        stats.requests,
        stats.failed,
        stats.steps,
        stats.peak_pages,
        stats.pages_pruned,
        stats.mean_live,
        stats.peak_live,
    );
    if cfg.serve.prefix_cache.is_some() {
        let px = &stats.prefix;
        println!(
            "prefix-cache: hits={} misses={} inserted={} evicted={} pages_nominal={}",
            px.hits, px.misses, px.inserted, px.evicted, px.pages_nominal
        );
    }
    if cfg.serve.kv_tier.is_some() {
        println!(
            "kv-tier: demoted={} promoted={} err_ratio={:.3} capacity_peak={:.2}x",
            stats.pages_demoted,
            stats.pages_promoted,
            stats.tier_error_ratio,
            stats.capacity_ratio_peak,
        );
    }
    println!(
        "tokens={} wall={:.2}s thpt={:.1} tok/s | TTFT p50={:.1}ms p95={:.1}ms p99={:.1}ms | \
         tok p50={:.1}ms p95={:.1}ms p99={:.1}ms",
        stats.tokens_out,
        stats.wall_s,
        stats.tok_s,
        stats.ttft.p50 * 1e3,
        stats.ttft.p95 * 1e3,
        stats.ttft.p99 * 1e3,
        stats.token_lat.p50 * 1e3,
        stats.token_lat.p95 * 1e3,
        stats.token_lat.p99 * 1e3,
    );
    Ok(())
}

/// The deprecated artifact-driven wave router, kept behind `--legacy`.
#[allow(deprecated)]
fn cmd_serve_legacy(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let variant = args.str_or("variant", "sfa_k8");
    let n_requests = args.usize_or("requests", 16)?;
    let workers = args.usize_or("workers", 2)?;
    let batch = args.usize_or("batch", 4)?;
    let max_new = args.usize_or("max-new", 16)?;
    let rt = Runtime::new(&dir)?;
    let vocab = rt.manifest.variant(&variant)?.cfg_usize("vocab")? as i32;
    let prefill_seq = rt.manifest.prefill_seq;
    drop(rt);

    let router = Router::start(RouterConfig {
        artifact_dir: dir,
        variant,
        workers,
        batch_size: batch,
        max_wait: std::time::Duration::from_millis(50),
        sampling_temperature: None,
        queue_capacity: args.usize_or("queue-capacity", 1024)?,
    });
    let mut rng = Rng::new(args.u64_or("seed", 1)?);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|_| {
            let plen = rng.range(4, prefill_seq.min(64));
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
            router.submit(prompt, max_new)
        })
        .collect::<std::result::Result<_, _>>()?;
    let mut metrics = ServeMetrics::default();
    for rx in rxs {
        let resp = rx.recv()?;
        metrics.record(&resp);
    }
    metrics.wall_s = t0.elapsed().as_secs_f64();
    router.shutdown()?;
    println!("{}", metrics.summary());
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts_dir(args))?;
    let lr = args.f64_or("lr", 1e-3)? as f32;
    let eval_batches = args.usize_or("eval-batches", 4)?;
    match args.command.get(1).map(|s| s.as_str()) {
        Some("table1") => {
            let steps = args.usize_or("steps", 200)?;
            let variants = args.str_list_or(
                "variants", &["dense", "sfa_k8", "sfa_k16", "short_d32"],
            );
            let (t, reports) = experiments::table1(&rt, &variants, steps, lr, eval_batches)?;
            t.print();
            if let Some(path) = args.get("loss-log") {
                let mut out = String::new();
                for r in &reports {
                    for (i, l) in r.losses.iter().enumerate() {
                        out.push_str(&format!("{}\t{}\t{}\n", r.variant, i, l));
                    }
                }
                std::fs::write(path, out)?;
            }
        }
        Some("table2") => {
            let steps = args.usize_or("steps", 300)?;
            let variants =
                args.str_list_or("variants", &["dense", "sfa_k2", "sfa_k8", "short_d16"]);
            let lengths = args.usize_list_or("lengths", &[64, 128, 256, 512])?;
            experiments::table2(&rt, &variants, steps, lr, &lengths, eval_batches)?.print();
        }
        Some("table3") => {
            let pre = args.usize_or("pre-steps", 200)?;
            let ft = args.usize_or("ft-steps", 60)?;
            let lam = args.f64_or("lambda", 1.0)? as f32;
            let variant = args.str_or("variant", "sfa_k8");
            experiments::table3(&rt, &variant, pre, ft, lr, lam, eval_batches)?.print();
        }
        Some("fig8") => {
            let steps = args.usize_or("steps", 150)?;
            let ks = args.usize_list_or("ks", &[2, 4, 8, 16])?;
            let (t, curves) = experiments::fig8(&rt, &ks, steps, lr, eval_batches)?;
            t.print();
            if let Some(path) = args.get("loss-log") {
                let mut out = String::new();
                for (k, losses) in &curves {
                    for (i, l) in losses.iter().enumerate() {
                        out.push_str(&format!("k{}\t{}\t{}\n", k, i, l));
                    }
                }
                std::fs::write(path, out)?;
                println!("loss curves written to {path} (Fig 10 data)");
            }
        }
        Some("table12") => {
            let steps = args.usize_or("steps", 200)?;
            let variants = args.str_list_or("variants", &["dense", "sfa_k8"]);
            let lengths = args.usize_list_or("lengths", &[64, 128, 256])?;
            experiments::table12(&rt, &variants, steps, lr, &lengths, eval_batches)?.print();
        }
        other => bail!("unknown experiment {other:?} — see README §Experiments"),
    }
    Ok(())
}

/// Split and validate a `--engines "spec;spec;..."` list so bad specs
/// surface the registry's descriptive error instead of a panic deep in
/// the bench layer.
fn parse_spec_list(s: &str) -> Result<Vec<String>> {
    let specs = sfa::attention::registry::split_spec_list(s);
    for spec in &specs {
        sfa::attention::registry::parse_spec(spec)?;
    }
    Ok(specs)
}

/// Sparsity budget for the cost-model tables: `--engine SPEC` wins
/// (its feature budget), else `--k`, else the default.
fn engine_k(args: &Args, default_k: usize) -> Result<usize> {
    if let Some(spec) = args.get("engine") {
        if let Some(k) = sfa::attention::registry::parse_spec(spec)?.feature_k() {
            return Ok(k);
        }
    }
    args.usize_or("k", default_k)
}

fn cmd_bench(args: &Args) -> Result<()> {
    let budget = args.f64_or("budget", 0.5)?;
    match args.command.get(1).map(|s| s.as_str()) {
        Some("serve") => {
            // Mixed-length wave-vs-continuous comparison with a KV
            // eviction policy sweep (prompts 32–1024 by default, per
            // the serving story).
            let mut cfg = serve_workload_cfg(args, 32, (32, 1024), (8, 96))?;
            if args.get("lanes").is_none() {
                // Sweep default: enough lanes that the page budget,
                // not the lane cap, is what policy admission relaxes.
                cfg.serve.max_lanes = 32;
            }
            if args.get("kv-tier").is_some() {
                // Tiered-KV comparison: the same workload all-fp32,
                // under the configured int8 cold tier, and under a tier
                // that can never fire (the bit-for-bit identity pin).
                if args.get("replicas").is_some()
                    || args.get("speculate").is_some()
                    || args.has("prefix-cache")
                    || args.has("prefill-chunk")
                    || args.get("prefill-chunk").is_some()
                {
                    bail!(
                        "--kv-tier, --replicas, --speculate, --prefix-cache, and \
                         --prefill-chunk are separate bench comparisons — pick one"
                    );
                }
                let tier = cfg.serve.kv_tier.expect("serve_config parsed --kv-tier");
                cfg.serve.kv_tier = None; // bench_serve_tiered toggles it per run
                cfg.tiered = Some(tier);
                let (table, cmp) = serve_bench::bench_serve_tiered(&cfg);
                table.print();
                let path = args.str_or("serve-json", "BENCH_serve_tiered.json");
                std::fs::write(&path, serve_bench::tiered_to_json(&cfg, &cmp))?;
                println!("\n[bench] wrote tiered-KV comparison to {path}");
                if !cmp.streams_identical_no_trigger {
                    bail!("an untriggered cold tier changed token streams — correctness bug");
                }
                return Ok(());
            }
            if args.get("replicas").is_some() {
                // Multi-replica router comparison: the same arrival
                // trace driven through the SLO-aware ReplicaRouter and
                // a round-robin baseline (plus a single-replica stream
                // reference), goodput and interactive TTFT recorded.
                if args.has("prefix-cache")
                    || args.has("prefill-chunk")
                    || args.get("speculate").is_some()
                {
                    bail!(
                        "--replicas, --speculate, --prefix-cache, and --prefill-chunk \
                         are separate bench comparisons — pick one"
                    );
                }
                if cfg.serve.kv_policy.is_some() {
                    bail!(
                        "--replicas and --policy are mutually exclusive (affinity \
                         routing probes the radix prefix cache, which a policy-pruned \
                         lane cannot serve)"
                    );
                }
                if args.get("lanes").is_none() {
                    // Router default: few lanes per replica so queueing
                    // pressure (what the cost model routes around) is
                    // actually exercised.
                    cfg.serve.max_lanes = 4;
                }
                let slo = SloClass::parse(&args.str_or("slo", "interactive"))
                    .map_err(|e| anyhow::anyhow!("--slo: {e}"))?;
                let (ttft_s, tpot_s) = match slo {
                    SloClass::Interactive { ttft_s, tpot_s } => (ttft_s, tpot_s),
                    SloClass::Batch => bail!(
                        "--slo must be an interactive class (batch has no deadlines \
                         to route against)"
                    ),
                };
                let interactive_frac = args.f64_or("interactive-frac", 0.5)?;
                if !(0.0..=1.0).contains(&interactive_frac) {
                    bail!("--interactive-frac must be in [0, 1]");
                }
                let rb = serve_bench::RouterBenchConfig {
                    replicas: args.usize_or("replicas", 2)?,
                    interactive_frac,
                    ttft_s,
                    tpot_s,
                    system_prompts: args.usize_or("system-prompts", 4)?,
                    system_prompt_len: args.usize_or("system-prompt-len", 64)?,
                    cache_pages: args.usize_or("prefix-pages", 1024)?,
                    burst_len: args.usize_or("burst-len", 8)?,
                    burst_rate: args.f64_or("burst-rate", 2.0)?,
                    burst_gap_steps: args.usize_or("burst-gap", 12)?,
                    tail_alpha: args.f64_or("tail-alpha", 1.2)?,
                };
                if rb.replicas < 1 {
                    bail!("--replicas must be >= 1");
                }
                if rb.cache_pages < 1 {
                    bail!("--prefix-pages must be >= 1");
                }
                if rb.system_prompt_len + 2 > cfg.prompt_max {
                    bail!(
                        "--system-prompt-len {} leaves no suffix room under --prompt-max {}",
                        rb.system_prompt_len,
                        cfg.prompt_max
                    );
                }
                cfg.serve.prefix_cache = None; // bench_serve_router installs its own
                cfg.router = Some(rb);
                let (table, cmp) = serve_bench::bench_serve_router(&cfg);
                table.print();
                let path = args.str_or("serve-json", "BENCH_serve_router.json");
                std::fs::write(&path, serve_bench::router_to_json(&cfg, &cmp))?;
                println!("\n[bench] wrote multi-replica router comparison to {path}");
                if !cmp.streams_identical {
                    bail!("replica placement changed token streams — correctness bug");
                }
                return Ok(());
            }
            if args.get("speculate").is_some() {
                // Speculative-decoding comparison: the same workload run
                // plain and with draft-and-verify lanes, streams pinned
                // bit-for-bit, acceptance rate and tokens/step recorded.
                if args.has("prefix-cache") || args.has("prefill-chunk") {
                    bail!(
                        "--speculate, --prefix-cache, and --prefill-chunk are separate \
                         bench comparisons — pick one"
                    );
                }
                if cfg.serve.kv_policy.is_some() {
                    bail!("--speculate and --policy are mutually exclusive");
                }
                let sp = cfg.serve.speculate.expect("serve_config parsed --speculate");
                cfg.serve.speculate = None; // bench_serve_spec toggles it per run
                cfg.speculate = Some(sp);
                let (table, cmp) = serve_bench::bench_serve_spec(&cfg);
                table.print();
                let path = args.str_or("serve-json", "BENCH_serve_spec.json");
                std::fs::write(&path, serve_bench::spec_to_json(&cfg, &cmp))?;
                println!("\n[bench] wrote speculative-decoding comparison to {path}");
                if !cmp.streams_identical {
                    bail!("speculative decoding changed token streams — correctness bug");
                }
                return Ok(());
            }
            if args.has("prefill-chunk") || args.get("prefill-chunk").is_some() {
                // Chunked-prefill interference comparison: one long
                // prompt submitted ahead of a fleet of short decode
                // lanes, the whole stream re-run per chunk size
                // (chunk 0 = monolithic baseline). Measures how far
                // chunking shields decode-lane TTFT from long-prompt
                // admission stalls.
                if args.has("prefix-cache") || cfg.serve.prefix_cache.is_some() {
                    bail!(
                        "--prefill-chunk and --prefix-cache are separate bench \
                         comparisons — pick one"
                    );
                }
                let mut ck = serve_bench::ChunkedBenchConfig {
                    long_prompt: args.usize_or("long-prompt", 4096)?,
                    long_max_new: args.usize_or("long-max-new", 8)?,
                    decode_lanes: args.usize_or("decode-lanes", 8)?,
                    decode_prompt: args.usize_or("decode-prompt", 16)?,
                    decode_max_new: args.usize_or("decode-max-new", 32)?,
                    chunks: args.usize_list_or("chunks", &[0, 64, 256, 1024])?,
                };
                // `--prefill-chunk N` narrows the sweep to {0, N};
                // an explicit `--chunks` list wins over both.
                let n = args.usize_or("prefill-chunk", 0)?;
                if n > 0 && args.get("chunks").is_none() {
                    ck.chunks = vec![0, n];
                }
                if !ck.chunks.contains(&0) {
                    ck.chunks.insert(0, 0);
                }
                cfg.serve.kv_policy = None;
                cfg.chunked = Some(ck);
                let (table, cmp) = serve_bench::bench_serve_chunked(&cfg);
                table.print();
                let path = args.str_or("serve-json", "BENCH_serve.json");
                std::fs::write(
                    &path,
                    serve_bench::to_json_full(&cfg, &[], None, Some(&cmp)),
                )?;
                println!("\n[bench] wrote chunked-prefill comparison to {path}");
                if !cmp.streams_identical {
                    bail!("chunked prefill changed greedy token streams — correctness bug");
                }
                return Ok(());
            }
            if args.has("prefix-cache") {
                // Prefix-cache comparison: cold vs radix prefix cache
                // on a repeated-system-prompt workload (the serving
                // shape the paper's KV-halving claim cares about).
                if cfg.serve.kv_policy.is_some() {
                    bail!("--prefix-cache and --policy are mutually exclusive");
                }
                let system_prompt =
                    args.usize_or("system-prompt", (cfg.prompt_max / 2).max(1))?;
                if system_prompt + 2 > cfg.prompt_max {
                    bail!(
                        "--system-prompt {} leaves no suffix room under --prompt-max {}",
                        system_prompt,
                        cfg.prompt_max
                    );
                }
                cfg.serve.kv_policy = None;
                cfg.serve.prefix_cache = None; // bench_serve_prefix sets its own
                cfg.prefix = Some(PrefixBenchConfig {
                    system_prompt,
                    cache_pages: args.usize_or("prefix-pages", 1024)?,
                });
                let (table, cmp) = serve_bench::bench_serve_prefix(&cfg);
                table.print();
                let runs = vec![cmp.cold.clone(), cmp.warm.clone()];
                let path = args.str_or("serve-json", "BENCH_serve.json");
                std::fs::write(
                    &path,
                    serve_bench::to_json_with_prefix(&cfg, &runs, Some(&cmp)),
                )?;
                println!("\n[bench] wrote prefix-cache comparison to {path}");
                if !cmp.streams_identical {
                    bail!("prefix cache changed greedy token streams — correctness bug");
                }
                return Ok(());
            }
            // `--policies` wins; a lone `--policy X` narrows the sweep
            // to that policy (instead of being silently ignored);
            // otherwise sweep the full default set.
            let default_policies = match args.get("policy") {
                Some(p) => p.to_string(),
                None => "none;h2o;snapkv;quest".to_string(),
            };
            cfg.policies = args
                .str_or("policies", &default_policies)
                .split(';')
                .filter(|s| !s.trim().is_empty())
                .map(|s| PagedKvPolicy::parse(s).map_err(|e| anyhow::anyhow!("--policies: {e}")))
                .collect::<Result<Vec<_>>>()?;
            if cfg.policies.is_empty() {
                bail!("--policies needs at least one entry");
            }
            // The wave baseline runs policy-free, and each swept policy
            // gets its own admission math — the workload must fit all
            // of them or drive() would hit a submit rejection.
            check_workload_fits(&cfg, None)?;
            for pol in &cfg.policies {
                check_workload_fits(&cfg, *pol)?;
            }
            let (table, runs) = serve_bench::bench_serve(&cfg);
            table.print();
            let path = args.str_or("serve-json", "BENCH_serve.json");
            std::fs::write(&path, serve_bench::to_json(&cfg, &runs))?;
            println!("\n[bench] wrote scheduling comparison to {path}");
            return Ok(());
        }
        Some("fig1") => {
            figures::fig1(args.usize_or("ctx", 131072)?, engine_k(args, 16)?).print()
        }
        Some("fig3") => figures::fig3(
            args.usize_or("ctx", 4096)?,
            args.usize_or("d", 128)?,
            &args.usize_list_or("ks", &[2, 8, 16, 32])?,
            budget,
        )
        .print(),
        Some("fig5") => figures::fig5(
            &args.usize_list_or("ctxs", &[1024, 4096, 16384, 65536, 262144])?,
            args.usize_or("d", 64)?,
            engine_k(args, 4)?,
        )
        .print(),
        Some("fig6") => {
            let k = args.usize_or("k", 8)?;
            let spec = args.str_or("engine", &format!("sfa:k={k}"));
            sfa::attention::registry::parse_spec(&spec)?;
            let (a, b) = figures::fig6_spec(
                &args.usize_list_or("ctxs", &[512, 1024, 2048, 4096, 8192])?,
                args.usize_or("d", 128)?,
                k,
                &spec,
                budget,
            );
            a.print();
            b.print();
        }
        Some("engines") => {
            let specs = parse_spec_list(
                &args.str_or("engines", "flash_dense;sfa:k=8;sfa:k=8,skip=on"),
            )?;
            figures::engine_grid(
                &specs,
                &args.usize_list_or("ctxs", &[1024, 4096])?,
                args.usize_or("d", 128)?,
                budget,
            )
            .print()
        }
        Some("table6") => {
            figures::table6(&args.usize_list_or("ctxs", &[8192, 16384, 32768, 65536])?).print()
        }
        Some("table7") => figures::table7(
            args.usize_or("ctx", 4096)?,
            args.usize_or("d", 128)?,
            args.usize_or("k", 8)?,
            budget,
        )
        .print(),
        Some("table8") => figures::table8(
            &args.usize_list_or("ctxs", &[1024, 4096, 8192, 16384, 32768, 65536])?,
            args.usize_or("d", 128)?,
            args.usize_or("k", 16)?,
            budget,
        )
        .print(),
        Some("table9") | Some("fig4") => figures::table9(
            &args.usize_list_or("ctxs", &[1024, 4096, 8192, 16384])?,
            &args.usize_list_or("dims", &[64, 128, 256])?,
            &args.usize_list_or("ks", &[2, 4, 8, 16, 32])?,
            budget,
        )
        .print(),
        Some("table10") => {
            let ctx = args.usize_or("ctx", 4096)?;
            let d = args.usize_or("d", 128)?;
            let k = args.usize_or("k", 8)?;
            let specs = match args.get("engines") {
                Some(s) => parse_spec_list(s)?,
                None => figures::table10_specs(ctx, d, k),
            };
            figures::table10_latency_specs(&specs, ctx, d, budget).print()
        }
        other => bail!("unknown bench target {other:?}"),
    }
    let path = args.str_or("bench-json", "BENCH_attention.json");
    let written = sfa::bench::write_records(&path)?;
    if written > 0 {
        println!("\n[bench] wrote {written} engine records to {path}");
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    match args.command.get(1).map(|s| s.as_str()) {
        Some("memory") => {
            use sfa::sparse::memory::{memory_ratio, paper_ratio_approx, Widths};
            let mut t = sfa::bench::Table::new(
                "Appendix J — dense/CSR memory ratio (fp16/int8/int32 widths)",
                &["d", "k", "exact ratio", "2d/(3k+4)"],
            );
            for &d in &[64usize, 128, 256, 1024] {
                for &k in &[4usize, 8, 16, 32] {
                    if k >= d {
                        continue;
                    }
                    t.row(vec![
                        d.to_string(),
                        k.to_string(),
                        format!("{:.2}", memory_ratio(65536, d, k, Widths::PAPER)),
                        format!("{:.2}", paper_ratio_approx(d, k)),
                    ]);
                }
            }
            t.print();
        }
        Some("session") => {
            use sfa::attention::registry::parse_spec;
            use sfa::attention::session::{AttentionSession, SessionConfig};
            use sfa::attention::{Engine, HeadTensor};
            use sfa::bench::table::fmt_time;

            let spec = args.str_or("engine", "sfa:k=8");
            let parsed = parse_spec(&spec)?;
            let batch = args.usize_or("batch", 1)?;
            let heads = args.usize_or("heads", 4)?;
            let d = args.usize_or("d", 64)?;
            let prefill_n = args.usize_or("ctx", 256)?;
            let steps = args.usize_or("steps", 32)?;
            let n = prefill_n + steps;
            let cfg = SessionConfig::new(batch, heads, d, d)
                .with_paging(args.usize_or("page-size", 16)?, 1 << 20);
            let mut sess = AttentionSession::from_spec(&spec, cfg)?;
            let mut rng = Rng::new(args.u64_or("seed", 0)?);
            let q = HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0);
            let k = HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0);
            let v = HeadTensor::randn(batch, heads, n, d, &mut rng, 1.0);
            // Oracle: one-shot causal prefill over the whole sequence.
            let full = parsed.build().forward_batched(&q, &k, &v, true);
            let t0 = std::time::Instant::now();
            let pre = sess.prefill(
                &q.slice_rows(0, prefill_n),
                &k.slice_rows(0, prefill_n),
                &v.slice_rows(0, prefill_n),
                true,
            )?;
            let prefill_s = t0.elapsed().as_secs_f64();
            let mut max_err = 0f32;
            for b in 0..batch {
                for h in 0..heads {
                    for t in 0..prefill_n {
                        for (a, e) in
                            pre.head_row(b, h, t).iter().zip(full.head_row(b, h, t))
                        {
                            max_err = max_err.max((a - e).abs());
                        }
                    }
                }
            }
            let t1 = std::time::Instant::now();
            for s in 0..steps {
                let t = prefill_n + s;
                let o = sess.decode_step(
                    &q.slice_rows(t, t + 1),
                    &k.slice_rows(t, t + 1),
                    &v.slice_rows(t, t + 1),
                )?;
                for b in 0..batch {
                    for h in 0..heads {
                        for (a, e) in
                            o.head_row(b, h, 0).iter().zip(full.head_row(b, h, t))
                        {
                            max_err = max_err.max((a - e).abs());
                        }
                    }
                }
            }
            let decode_s = t1.elapsed().as_secs_f64();
            let mut t = sfa::bench::Table::new(
                &format!("AttentionSession lifecycle vs one-shot prefill ({})", sess.engine_name()),
                &["metric", "value"],
            );
            t.row(vec!["engine spec".into(), sess.spec().canonical()]);
            t.row(vec!["cache scorer".into(), sess.scorer().label()]);
            t.row(vec!["batch × heads".into(), format!("{batch} × {heads}")]);
            t.row(vec!["tokens (prefill + decode)".into(), format!("{prefill_n} + {steps}")]);
            t.row(vec!["KV pages in use".into(), sess.pages_in_use().to_string()]);
            t.row(vec![
                "KV cache MB".into(),
                format!("{:.2}", sess.cache_bytes() as f64 / 1e6),
            ]);
            t.row(vec!["prefill wall".into(), fmt_time(prefill_s)]);
            t.row(vec![
                "decode wall / step".into(),
                fmt_time(decode_s / steps.max(1) as f64),
            ]);
            t.row(vec!["max |err| vs one-shot".into(), format!("{max_err:.2e}")]);
            t.print();
        }
        Some(which @ ("entropy" | "svd")) => {
            let rt = Runtime::new(artifacts_dir(args))?;
            let variant = args.str_or("variant", "sfa_k8");
            let steps = args.usize_or("steps", 50)?;
            let k = args.usize_or("k", 8)?;
            // Short training run so the activations are "trained", then
            // pull per-layer Q/K via the qk_acts artifact.
            let (trainer, _) = experiments::train_variant(
                &rt, &variant, CorpusKind::Zipf, steps,
                args.f64_or("lr", 1e-3)? as f32, 42, 0,
            )?;
            let acts = qk_acts(&rt, &trainer, &variant)?;
            if which == "entropy" {
                let mut t = sfa::bench::Table::new(
                    &format!(
                        "Fig 7 — top-{k} selection entropy per (layer, head), \
                         {variant}, {steps} steps"
                    ),
                    &["layer", "tensor", "per-head entropy"],
                );
                for (layer, (qs, ks_)) in acts.iter().enumerate() {
                    for (name, heads) in [("Q", qs), ("K", ks_)] {
                        let es: Vec<String> = heads
                            .iter()
                            .map(|m| {
                                format!(
                                    "{:.3}",
                                    sfa::analysis::entropy::selection_entropy(m, k)
                                )
                            })
                            .collect();
                        t.row(vec![layer.to_string(), name.into(), es.join(" ")]);
                    }
                }
                t.print();
            } else {
                let tau = args.f64_or("tau", 0.9)? as f32;
                let mut t = sfa::bench::Table::new(
                    &format!("Fig 11 — effective rank (τ={tau}) per (layer, head), {variant}"),
                    &["layer", "tensor", "d_head", "per-head effective rank"],
                );
                for (layer, (qs, ks_)) in acts.iter().enumerate() {
                    for (name, heads) in [("Q", qs), ("K", ks_)] {
                        let rs: Vec<String> = heads
                            .iter()
                            .map(|m| sfa::analysis::svd::effective_rank(m, tau).to_string())
                            .collect();
                        t.row(vec![
                            layer.to_string(),
                            name.into(),
                            heads[0].cols.to_string(),
                            rs.join(" "),
                        ]);
                    }
                }
                t.print();
            }
        }
        other => bail!("unknown analysis {other:?}"),
    }
    Ok(())
}

/// Run the qk_acts artifact on a fresh corpus batch and split the
/// outputs into per-layer, per-head matrices of shape (B·S, dq).
fn qk_acts(
    rt: &Runtime,
    trainer: &Trainer,
    variant: &str,
) -> Result<Vec<(Vec<sfa::util::matrix::Matrix>, Vec<sfa::util::matrix::Matrix>)>> {
    use sfa::util::matrix::Matrix;
    let v = rt.manifest.variant(variant)?;
    let e = v.entry("qk_acts")?;
    let vocab = v.cfg_usize("vocab")?;
    let (b, s) = (e.batch, e.seq);
    let mut corpus = sfa::train::ZipfCorpus::new(vocab, 123);
    let tokens = corpus.batch(b, s);
    let mut args_: Vec<xla::Literal> = Vec::new();
    for p in trainer.params() {
        args_.push(sfa::train::trainer::clone_literal(p)?);
    }
    args_.push(HostTensor::I32(tokens, vec![b, s]).to_literal()?);
    let outs = rt.run(variant, "qk_acts", &args_)?;
    // Outputs alternate q, k per layer; each is (B, H, S, dq).
    let mut layers = Vec::new();
    let mut it = outs.iter();
    while let (Some(q), Some(k)) = (it.next(), it.next()) {
        let mut pair = (Vec::new(), Vec::new());
        for (lit, dst) in [(q, &mut pair.0), (k, &mut pair.1)] {
            let t = HostTensor::from_literal(lit)?;
            let shape = t.shape().to_vec();
            let (bb, h, ss, dq) = (shape[0], shape[1], shape[2], shape[3]);
            let data = t.as_f32()?;
            for head in 0..h {
                let mut m = Matrix::zeros(bb * ss, dq);
                for batch in 0..bb {
                    for pos in 0..ss {
                        let src = ((batch * h + head) * ss + pos) * dq;
                        let dst_row = batch * ss + pos;
                        m.row_mut(dst_row).copy_from_slice(&data[src..src + dq]);
                    }
                }
                dst.push(m);
            }
        }
        layers.push(pair);
    }
    Ok(layers)
}
