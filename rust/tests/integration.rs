//! Integration tests over the full three-layer stack: AOT artifacts →
//! PJRT runtime → trainer / serving engine. Requires `make artifacts`
//! (tests self-skip with a notice when the directory is missing so
//! plain `cargo test` stays green in a fresh checkout).

// These tests pin the deprecated wave path (`Engine::run_wave`) — it
// must keep working as a shim while `serve` is the primary API.
#![allow(deprecated)]

use sfa::coordinator::engine::{Engine, Sampling};
use sfa::coordinator::request::GenRequest;
use sfa::runtime::{HostTensor, Runtime};
use sfa::train::corpus::{niah_batch, ZipfCorpus};
use sfa::train::trainer::Trainer;
use sfa::util::rng::Rng;

const DIR: &str = "artifacts";

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new(DIR).join("manifest.json").exists() {
        eprintln!("SKIP: {DIR}/manifest.json missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new(DIR).expect("runtime"))
}

#[test]
fn manifest_lists_expected_variants_and_entries() {
    let Some(rt) = runtime() else { return };
    for v in ["dense", "sfa_k8"] {
        let vm = rt.manifest.variant(v).unwrap();
        for e in ["train_step", "eval_step", "logits", "prefill_b1", "decode_b1"] {
            assert!(vm.entries.contains_key(e), "{v} missing {e}");
        }
    }
}

#[test]
fn weights_load_and_match_manifest() {
    let Some(rt) = runtime() else { return };
    let w = rt.load_weights("sfa_k8").unwrap();
    let vm = rt.manifest.variant("sfa_k8").unwrap();
    assert_eq!(w.len(), vm.params.len());
}

#[test]
fn eval_loss_near_uniform_at_init() {
    let Some(rt) = runtime() else { return };
    for variant in ["dense", "sfa_k8"] {
        let trainer = Trainer::new(&rt, variant).unwrap();
        let vocab = rt.manifest.variant(variant).unwrap().cfg_usize("vocab").unwrap();
        let mut corpus = ZipfCorpus::new(vocab, 3);
        let tokens = corpus.batch(trainer.batch, trainer.seq);
        let loss = trainer.eval_loss(&tokens).unwrap();
        let uniform = (vocab as f32).ln();
        assert!(
            (loss - uniform).abs() < 0.75,
            "{variant}: init loss {loss} vs ln(V)={uniform}"
        );
    }
}

#[test]
fn train_step_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut trainer = Trainer::new(&rt, "sfa_k8").unwrap();
    let vocab = rt.manifest.variant("sfa_k8").unwrap().cfg_usize("vocab").unwrap();
    let mut corpus = ZipfCorpus::new(vocab, 4);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..6 {
        let tokens = corpus.batch(trainer.batch, trainer.seq);
        last = trainer.train_step(&tokens, 2e-3).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap() - 0.1, "{} -> {last}", first.unwrap());
    assert_eq!(trainer.steps_done, 6);
}

#[test]
fn prefill_decode_consistent_with_logits_entry() {
    // Greedy decode through the serving path must match the argmax of
    // the full-forward logits entry at every generated position — this
    // pins the sparse-KV decode cache against the training-path model.
    let Some(rt) = runtime() else { return };
    for variant in ["dense", "sfa_k8"] {
        let vm = rt.manifest.variant(variant).unwrap();
        let vocab = vm.cfg_usize("vocab").unwrap() as i32;
        let mut engine = Engine::new(&rt, variant, 1, Sampling::Greedy, 0).unwrap();
        let mut rng = Rng::new(9);
        let prompt: Vec<i32> = (0..24).map(|_| rng.below(vocab as u64) as i32).collect();
        let out = engine
            .run_wave(&[GenRequest::new(0, prompt.clone(), 6)], 0)
            .unwrap();
        let gen = &out[0].tokens;
        assert_eq!(gen.len(), 6);

        // Reference: run the logits entry on prompt + generated prefix.
        let e = vm.entry("logits").unwrap();
        let (b, s) = (e.batch, e.seq);
        let mut full = prompt.clone();
        full.extend_from_slice(&gen[..gen.len() - 1]);
        let mut grid = vec![0i32; b * s];
        grid[..full.len()].copy_from_slice(&full);
        let mut args: Vec<xla::Literal> = Vec::new();
        for p in rt.load_weights(variant).unwrap() {
            args.push(p);
        }
        args.push(
            HostTensor::I32(grid, vec![b, s]).to_literal().unwrap(),
        );
        let outs = rt.run(variant, "logits", &args).unwrap();
        let logits = HostTensor::from_literal(&outs[0]).unwrap();
        let lf = logits.as_f32().unwrap();
        let v = vocab as usize;
        for (t, &tok) in gen.iter().enumerate() {
            let pos = prompt.len() - 1 + t; // logits at pos predict pos+1
            let row = &lf[pos * v..(pos + 1) * v];
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            assert_eq!(
                argmax, tok,
                "{variant}: step {t} diverges (pos {pos})"
            );
        }
    }
}

#[test]
fn partial_batch_waves_pad_and_discard() {
    let Some(rt) = runtime() else { return };
    let mut engine = Engine::new(&rt, "dense", 4, Sampling::Greedy, 0).unwrap();
    let reqs: Vec<GenRequest> = (0..2)
        .map(|i| GenRequest::new(i, vec![1 + i as i32, 2, 3, 4], 3))
        .collect();
    let out = engine.run_wave(&reqs, 0).unwrap();
    assert_eq!(out.len(), 2);
    assert!(out.iter().all(|r| r.tokens.len() == 3));
}

#[test]
fn niah_accuracy_at_chance_before_training() {
    let Some(rt) = runtime() else { return };
    let trainer = Trainer::new(&rt, "dense").unwrap();
    let vocab = rt.manifest.variant("dense").unwrap().cfg_usize("vocab").unwrap();
    let mut rng = Rng::new(5);
    let (flat, samples) = niah_batch(vocab, trainer.seq, trainer.batch, &mut rng);
    let acc = trainer.niah_accuracy(&flat, &samples).unwrap();
    // Untrained: near-chance (1/(vocab-4) ≈ 0.2%); anything above 30%
    // would indicate a scoring bug.
    assert!(acc < 0.3, "untrained NIAH accuracy suspicious: {acc}");
}

#[test]
fn qk_acts_entry_shapes() {
    let Some(rt) = runtime() else { return };
    let vm = rt.manifest.variant("sfa_k8").unwrap();
    let Ok(e) = vm.entry("qk_acts") else {
        eprintln!("SKIP: qk_acts not compiled");
        return;
    };
    let n_layers = vm.cfg_usize("n_layers").unwrap();
    // q + k per layer, plus the param_checksum keep-alive output.
    assert_eq!(e.outputs.len(), 2 * n_layers + 1);
    assert_eq!(e.outputs.last().unwrap().name, "param_checksum");
}
