//! Serving example: the full coordinator stack under a synthetic open
//! loop — router → batcher → engine workers → AOT prefill/decode with
//! the (sparse) KV cache. Reports TTFT/TPOT/throughput, comparing the
//! dense and SFA variants (the Latency columns of paper Tables 1/10).
//!
//! Run: `cargo run --release --example serve -- [artifacts] [requests]`

use std::time::{Duration, Instant};

use sfa::coordinator::router::{Router, RouterConfig};
use sfa::coordinator::ServeMetrics;
use sfa::runtime::Runtime;
use sfa::util::rng::Rng;

fn drive(dir: &str, variant: &str, n_requests: usize, vocab: i32, prefill_seq: usize)
    -> anyhow::Result<ServeMetrics>
{
    let router = Router::start(RouterConfig {
        artifact_dir: dir.to_string(),
        variant: variant.to_string(),
        workers: 1, // single-core testbed; bump on bigger hosts
        batch_size: 4,
        max_wait: Duration::from_millis(20),
        sampling_temperature: Some(0.8),
    });
    let mut rng = Rng::new(42);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|_| {
            let plen = rng.range(8, prefill_seq.min(96));
            let prompt: Vec<i32> =
                (0..plen).map(|_| rng.below(vocab as u64) as i32).collect();
            router.submit(prompt, 16)
        })
        .collect();
    let mut metrics = ServeMetrics::default();
    for rx in rxs {
        metrics.record(&rx.recv()?);
    }
    metrics.wall_s = t0.elapsed().as_secs_f64();
    router.shutdown()?;
    Ok(metrics)
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let dir = args.next().unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = args.next().map(|s| s.parse().unwrap()).unwrap_or(8);

    let rt = Runtime::new(&dir)?;
    let prefill_seq = rt.manifest.prefill_seq;
    let vocab = rt.manifest.variant("dense")?.cfg_usize("vocab")? as i32;
    drop(rt);

    for variant in ["dense", "sfa_k8"] {
        println!("== serving {n_requests} requests with {variant} ==");
        let m = drive(&dir, variant, n_requests, vocab, prefill_seq)?;
        println!("{}\n", m.summary());
    }
    Ok(())
}
