//! Serving example: the request-lifecycle `serve` API — build
//! requests, stream per-token events over a channel, and watch the
//! continuous batcher admit sequences into a live decode wave and
//! evict finished sequences' KV pages mid-wave.
//!
//! Runs entirely on the deterministic ToyLm substrate — no AOT
//! artifacts needed. (The deprecated artifact-driven wave router is
//! still reachable via `sfa serve --legacy`.)
//!
//! Run: `cargo run --release --example serve -- [requests]`

use sfa::serve::{
    ContinuousBatcher, RequestState, Scheduler, ServeConfig, ServeEvent, ServeRequest,
};
use sfa::util::rng::Rng;

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("requests must be an integer"))
        .unwrap_or(8);

    let cfg = ServeConfig::default();
    let mut sched = ContinuousBatcher::new(cfg);
    let (tx, rx) = std::sync::mpsc::channel::<ServeEvent>();

    // Mixed workload: different prompt lengths, generation budgets,
    // and engine families, all in one serving process.
    let mut rng = Rng::new(7);
    let specs = ["sfa:k=8", "dense", "window:w=64,scorer=sfa_k8"];
    for i in 0..n_requests {
        let plen = rng.range(16, 257);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        let req = ServeRequest::new(prompt)
            .max_new(rng.range(4, 33))
            .engine(specs[i % specs.len()])
            .events(tx.clone());
        // Backpressure is a typed error, not a panic: a real client
        // would retry after draining; the demo just stops submitting.
        match sched.submit(req) {
            Ok(id) => println!("submitted request {id} ({plen} prompt tokens)"),
            Err(e) => {
                println!("backpressure after {i} requests: {e}");
                break;
            }
        }
    }
    drop(tx);

    // Drive the scheduler; each step admits what fits the page budget,
    // decodes one token for every live sequence, and frees finished
    // lanes immediately.
    let t0 = std::time::Instant::now();
    let mut steps = 0;
    while sched.has_work() {
        let r = sched.step();
        steps += 1;
        if r.admitted > 0 || r.finished > 0 {
            println!(
                "step {steps:>3}: +{} admitted, {} live, {} finished, \
                 {} pages in use ({} freed)",
                r.admitted, r.live, r.finished, r.pages_in_use, r.pages_freed
            );
        }
    }

    // The streaming surface: every state transition and token arrived
    // on the channel as it happened.
    let mut tokens = 0usize;
    let mut finished = 0usize;
    for ev in rx.try_iter() {
        match ev {
            ServeEvent::Token { .. } => tokens += 1,
            ServeEvent::State { id, state: RequestState::Finished { reason } } => {
                println!("request {id} finished: {reason:?}");
                finished += 1;
            }
            ServeEvent::State { .. } => {}
        }
    }
    sched.metrics_mut().wall_s = t0.elapsed().as_secs_f64();
    println!("\nstreamed {tokens} tokens across {finished} requests in {steps} steps");
    println!("{}", sched.metrics().summary());
}
