//! Quickstart: the three-layer stack in one file.
//!
//! 1. load the AOT artifacts (L2 JAX model + L1 FlashSFA kernel,
//!    compiled to HLO by `make artifacts`);
//! 2. run a few training steps of the SFA variant from Rust;
//! 3. generate tokens through the serving path (prefill + sparse-KV
//!    decode);
//! 4. compare the CPU FlashSFA engine against dense attention on one
//!    head — the paper's core speed/quality trade in miniature.
//!
//! Run: `cargo run --release --example quickstart`

use sfa::attention::dense::DenseAttention;
use sfa::attention::flash_dense::FlashDense;
use sfa::attention::flash_sfa::FlashSfa;
use sfa::attention::Engine;
use sfa::coordinator::engine::{Engine as GenEngine, Sampling};
use sfa::coordinator::request::GenRequest;
use sfa::runtime::Runtime;
use sfa::train::corpus::CorpusKind;
use sfa::train::experiments;
use sfa::util::matrix::Matrix;
use sfa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // --- 1+2: train the SFA variant for a handful of steps ------------
    println!("== loading artifacts from {dir:?} and training sfa_k8 ==");
    let rt = Runtime::new(&dir)?;
    let (trainer, report) = experiments::train_variant(
        &rt, "sfa_k8", CorpusKind::Zipf, 5, 1e-3, 42, 1,
    )?;
    println!(
        "5 steps: loss {:.3} -> {:.3} ({:.0} tok/s)",
        report.losses[0], report.final_loss, report.tokens_per_s
    );
    let vocab = rt.manifest.variant("sfa_k8")?.cfg_usize("vocab")?;
    let ppl = experiments::eval_ppl(&trainer, CorpusKind::Zipf, vocab, 1, 7)?;
    println!("held-out PPL after 5 steps: {ppl:.1}");

    // --- 3: serving path (prefill + sparse-KV decode) ------------------
    println!("\n== generating through the SFA serving path ==");
    let mut engine = GenEngine::new(&rt, "sfa_k8", 1, Sampling::Temperature(1.0), 7)?;
    let prompt: Vec<i32> = (1..20).map(|i| (i * 3) % vocab as i32).collect();
    // Single-request wave through the artifact engine (the deprecated
    // wave path; see `examples/serve.rs` for the serve API).
    #[allow(deprecated)]
    let responses = engine.run_wave(&[GenRequest::new(0, prompt, 12)], 0)?;
    println!(
        "generated {:?} (TTFT {:.0}ms, total {:.0}ms)",
        responses[0].tokens,
        responses[0].ttft_s * 1e3,
        responses[0].total_s * 1e3
    );

    // --- 4: CPU FlashSFA engine vs dense --------------------------------
    println!("\n== CPU FlashSFA vs dense attention (one head, n=2048, d=128) ==");
    let mut rng = Rng::new(0);
    let n = 2048;
    let d = 128;
    let q = Matrix::randn(n, d, &mut rng, 1.0);
    let k = Matrix::randn(n, d, &mut rng, 1.0);
    let v = Matrix::randn(n, d, &mut rng, 1.0);

    let t0 = std::time::Instant::now();
    let dense_out = FlashDense::default().forward(&q, &k, &v, true);
    let t_dense = t0.elapsed();
    let t0 = std::time::Instant::now();
    let sfa_out = FlashSfa::new(8).forward(&q, &k, &v, true);
    let t_sfa = t0.elapsed();

    // Quality proxy: how close is SFA's output to exact attention?
    let exact = DenseAttention.forward(&q, &k, &v, true);
    let mut err = 0f32;
    for i in 0..exact.data.len() {
        err += (sfa_out.data[i] - exact.data[i]).powi(2);
    }
    let rel = err.sqrt() / exact.fro_norm();
    println!(
        "dense(flash): {:.1}ms | flash_sfa(k=8): {:.1}ms | speedup {:.2}x | \
         rel. output distance {rel:.3}",
        t_dense.as_secs_f64() * 1e3,
        t_sfa.as_secs_f64() * 1e3,
        t_dense.as_secs_f64() / t_sfa.as_secs_f64(),
    );
    let _ = dense_out;
    println!("\nquickstart OK");
    Ok(())
}
