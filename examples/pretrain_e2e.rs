//! End-to-end pretraining driver (the EXPERIMENTS.md §E2E run): trains
//! the dense, SFA and short-embedding variants for a few hundred steps
//! on the synthetic corpus via the AOT train_step, logs the loss curve,
//! evaluates held-out PPL, and prints the Table-1-shaped comparison —
//! all three layers composing (Pallas kernel → JAX model → Rust loop).
//!
//! Run: `cargo run --release --example pretrain_e2e -- \
//!          [artifacts] [steps] [variants,comma,separated]`

use sfa::runtime::Runtime;
use sfa::train::experiments;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let dir = args.next().unwrap_or_else(|| "artifacts".into());
    let steps: usize = args.next().map(|s| s.parse().unwrap()).unwrap_or(200);
    let variants: Vec<String> = args
        .next()
        .unwrap_or_else(|| "dense,sfa_k8,sfa_k16,short_d32".into())
        .split(',')
        .map(str::to_string)
        .collect();

    let rt = Runtime::new(&dir)?;
    println!(
        "pretraining {} variants for {steps} steps each on the Zipf corpus \
         (preset {}, {} params/variant)",
        variants.len(),
        rt.manifest.preset,
        rt.manifest
            .variant(&variants[0])
            .map(|v| v.params.iter().map(|p| p.numel()).sum::<usize>())
            .unwrap_or(0),
    );
    let (table, reports) = experiments::table1(&rt, &variants, steps, 1e-3, 4)?;
    table.print();

    // Loss curves (Fig-10-style stability check) to stdout tail + file.
    let mut log = String::new();
    for r in &reports {
        log.push_str(&format!("# {}\n", r.variant));
        for (i, l) in r.losses.iter().enumerate() {
            log.push_str(&format!("{i}\t{l}\n"));
        }
        let every = (r.losses.len() / 8).max(1);
        let curve: Vec<String> = r
            .losses
            .iter()
            .step_by(every)
            .map(|l| format!("{l:.3}"))
            .collect();
        println!("loss[{}]: {}", r.variant, curve.join(" -> "));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/pretrain_loss_curves.tsv", log)?;
    println!("loss curves written to results/pretrain_loss_curves.tsv");
    Ok(())
}
