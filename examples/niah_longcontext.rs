//! Long-context NIAH experiment (paper §4.2, Table 2): train dense /
//! SFA / short variants from scratch on synthetic needle-in-a-haystack
//! data (the `niah` preset artifacts: longer max_seq, small vocab),
//! then measure retrieval accuracy across held-out context lengths and
//! relative training speed.
//!
//! Run: `cargo run --release --example niah_longcontext -- \
//!          [artifacts-niah] [steps] [variants]`

use sfa::runtime::Runtime;
use sfa::train::experiments;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let dir = args.next().unwrap_or_else(|| "artifacts-niah".into());
    let steps: usize = args.next().map(|s| s.parse().unwrap()).unwrap_or(200);
    let variants: Vec<String> = args
        .next()
        .unwrap_or_else(|| "dense,sfa_k2,sfa_k8".into())
        .split(',')
        .map(str::to_string)
        .collect();

    let rt = Runtime::new(&dir)?;
    let max_seq = rt.manifest.max_seq;
    // Held-out eval lengths: 1/8 .. 1x of the trained window (the
    // paper's 1k..8k grid scaled to the CPU testbed window).
    let lengths: Vec<usize> = [8, 4, 2, 1].iter().map(|d| max_seq / d).collect();
    println!(
        "NIAH: training {:?} for {steps} steps at window {max_seq}, \
         evaluating at lengths {lengths:?}",
        variants
    );
    experiments::table2(&rt, &variants, steps, 1e-3, &lengths, 8)?.print();
    Ok(())
}
